//! One memo-table implementation for every value-pure cache in the crate.
//!
//! Both caching layers — the fleet calibrator/probe context
//! (`fleet::sim`) and the sweep evaluation context
//! (`offload::evalcache`) — memoize *pure functions*: every value is
//! fully determined by its key, so warm-up order, thread count, and even
//! wholesale eviction can only cost recomputation, never change a
//! result. [`Memo`] packages that contract once: a `BTreeMap` (ordered,
//! hash-DoS-free, deterministic iteration) plus hit/miss counters and an
//! optional capacity at which the table is cleared wholesale (the
//! `PLAN_MEMO_CAP` semantics the fleet probe context pioneered in PR 8).
//!
//! Counters are observability, not behavior: they feed the `sweep`
//! CLI's cache summary and `benches/sweep_scale.rs` hit-rate reporting.

use std::collections::BTreeMap;

/// A memo table for a value-pure function of `K`.
#[derive(Debug)]
pub struct Memo<K: Ord, V: Clone> {
    map: BTreeMap<K, V>,
    /// Clear-when-full bound (`None` = unbounded).
    cap: Option<usize>,
    hits: u64,
    misses: u64,
}

impl<K: Ord, V: Clone> Default for Memo<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Ord, V: Clone> Memo<K, V> {
    pub fn new() -> Self {
        Self {
            map: BTreeMap::new(),
            cap: None,
            hits: 0,
            misses: 0,
        }
    }

    /// A memo that clears itself wholesale when `cap` entries are
    /// resident and another insert arrives. Sound only because values
    /// are pure: dropping them costs recomputation, nothing else.
    pub fn with_cap(cap: usize) -> Self {
        Self {
            map: BTreeMap::new(),
            cap: Some(cap),
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Cached value for `key`, counting a hit or a miss. A miss is
    /// expected to be followed by [`Memo::insert`] once the value has
    /// been computed (the get/compute/insert split exists so callers can
    /// run the computation without holding any borrow of the memo).
    pub fn get(&mut self, key: &K) -> Option<V> {
        match self.map.get(key) {
            Some(v) => {
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert the computed value for a key (typically after a miss).
    /// Enforces the clear-when-full bound.
    pub fn insert(&mut self, key: K, value: V) {
        if let Some(cap) = self.cap {
            if self.map.len() >= cap {
                self.map.clear();
            }
        }
        self.map.insert(key, value);
    }

    /// Insert only if absent, without touching the hit/miss counters
    /// (the pre-warm idiom: results computed out-of-band are seeded into
    /// the table but were neither hits nor misses of the lazy path).
    pub fn seed(&mut self, key: K, value: V) {
        if let Some(cap) = self.cap {
            if self.map.len() >= cap {
                self.map.clear();
            }
        }
        self.map.entry(key).or_insert(value);
    }

    /// The classic memoized call: return the cached value or compute,
    /// store and return it. `f` runs with no borrow of the memo held.
    pub fn get_or_insert_with(&mut self, key: K, f: impl FnOnce() -> V) -> V {
        if let Some(v) = self.get(&key) {
            return v;
        }
        let v = f();
        self.insert(key, v.clone());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_insert_computes_once() {
        let mut m: Memo<u64, u64> = Memo::new();
        let mut calls = 0;
        let a = m.get_or_insert_with(7, || {
            calls += 1;
            42
        });
        let b = m.get_or_insert_with(7, || {
            calls += 1;
            99
        });
        assert_eq!((a, b, calls), (42, 42, 1));
        assert_eq!((m.hits(), m.misses()), (1, 1));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn cap_clears_wholesale_like_plan_memo() {
        let mut m: Memo<u64, u64> = Memo::with_cap(4);
        for k in 0..4 {
            m.insert(k, k);
        }
        assert_eq!(m.len(), 4);
        // The 5th insert finds the table at cap and clears it first.
        m.insert(4, 4);
        assert_eq!(m.len(), 1);
        assert_eq!(m.get(&4), Some(4));
        assert_eq!(m.get(&0), None, "evicted values recompute, never lie");
    }

    #[test]
    fn seed_does_not_overwrite_or_count() {
        let mut m: Memo<&'static str, u32> = Memo::new();
        m.seed("a", 1);
        m.seed("a", 2);
        assert_eq!(m.get(&"a"), Some(1));
        assert_eq!((m.hits(), m.misses()), (1, 0), "seeding is counter-neutral");
    }

    #[test]
    fn miss_then_insert_round_trips() {
        let mut m: Memo<(u32, bool), String> = Memo::new();
        assert_eq!(m.get(&(3, true)), None);
        m.insert((3, true), "v".to_string());
        assert_eq!(m.get(&(3, true)).as_deref(), Some("v"));
        assert_eq!((m.hits(), m.misses()), (1, 1));
    }
}
