//! Implementations of the CLI subcommands.

use anyhow::anyhow;

use super::{parse, CliDone};
use crate::fleet::{self, simulate_fleet_faulted, FaultTrace, FleetTrace, TraceGen};
use crate::mem::{engine, EngineRef, Policy};
use crate::model::footprint::{Footprint, Workload};
use crate::model::{presets as mpresets, ModelConfig};
use crate::offload::{
    schedules, simulate_iteration_report, sweep_grid_matrix_nocache, sweep_grid_matrix_with_ctx,
    EvalCtx, MemoryPlan, RunConfig, ScheduleRef,
};
use crate::optim::{adam_step, AdamHp, AdamState};
use crate::sim::memmodel::{OptLayout, OptimizerMemModel};
use crate::sim::{Dir, Fabric};
use crate::topology::{presets as tpresets, GpuId, NodeId, SystemTopology};
use crate::trow;
use crate::util::cli::CliSpec;
use crate::util::table::Table;
use crate::util::units::{fmt_bytes, fmt_rate, fmt_secs, GIB};

fn get_topo(name: &str, dram: Option<&str>) -> Result<SystemTopology, CliDone> {
    let t = tpresets::by_name(name)
        .ok_or_else(|| CliDone::Bad(format!("unknown preset {name:?} (config-a|config-b|dev-tiny)")))?;
    match dram {
        Some(d) => {
            let bytes = crate::util::units::parse_bytes(d).map_err(CliDone::Bad)?;
            Ok(tpresets::with_dram_capacity(t, bytes))
        }
        None => Ok(t),
    }
}

fn get_model(name: &str) -> Result<ModelConfig, CliDone> {
    mpresets::by_name(name)
        .ok_or_else(|| CliDone::Bad(format!("unknown model {name:?} (7b|12b|tiny|tiny-2m)")))
}

fn get_engine(name: &str) -> Result<EngineRef, CliDone> {
    engine::by_name(name).ok_or_else(|| {
        CliDone::Bad(format!(
            "unknown policy {name:?} ({})",
            engine::known_names().join("|")
        ))
    })
}

fn get_schedule(name: &str) -> Result<ScheduleRef, CliDone> {
    schedules::by_name(name).ok_or_else(|| {
        CliDone::Bad(format!(
            "unknown schedule {name:?} ({})",
            schedules::known_names().join("|")
        ))
    })
}

pub fn topo(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new("cxlfine topo", "print a hardware preset")
        .opt("preset", "config-a", "config-a | config-b | dev-tiny")
        .opt("dram", "", "override DRAM capacity, e.g. 128GiB");
    let a = parse(spec, args)?;
    let dram = a.get("dram").filter(|s| !s.is_empty());
    let t = get_topo(a.get("preset").unwrap(), dram)?;
    print!("{}", t.describe());
    Ok(())
}

pub fn plan(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new("cxlfine plan", "memory footprint + placement + tensor table")
        .opt("model", "12b", "7b | 12b | tiny | tiny-2m")
        .opt("preset", "config-a", "hardware preset")
        .opt("dram", "", "override DRAM capacity (e.g. 128GiB)")
        .opt("gpus", "2", "number of GPUs")
        .opt("batch", "16", "per-GPU batch size")
        .opt("context", "4096", "context length (tokens)")
        .opt(
            "policy",
            "cxl-aware",
            "placement policy (baseline|naive|cxl-aware|cxl-aware+striping|adaptive-spill|profile-aware)",
        )
        .opt(
            "schedule",
            "zero-offload",
            "schedule the tensor profiles are measured from",
        )
        .opt(
            "json",
            "",
            "write the tensor table (profile + placement + lifetime per region) to this JSON file",
        )
        .flag(
            "lifetime",
            "lifetime-aware capacity accounting: fit per-phase peak occupancy, not the static sum",
        );
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), a.get("dram").filter(|s| !s.is_empty()))?;
    let model = get_model(a.get("model").unwrap())?;
    let policy = get_engine(a.get("policy").unwrap())?;
    let schedule = get_schedule(a.get("schedule").unwrap())?;
    let lifetime = a.flag("lifetime");
    let w = Workload::new(
        a.parse_usize("gpus")?,
        a.parse_usize("batch")?,
        a.parse_usize("context")?,
    );
    let f = Footprint::compute(&model, &w);
    let mut t = Table::new(&["component", "precision", "bytes"]).left(0);
    t.row(trow!["model parameters", "bf16", fmt_bytes(f.params_bf16)]);
    t.row(trow!["gradients", "bf16", fmt_bytes(f.grads_bf16)]);
    t.row(trow!["checkpointed activations", "bf16", fmt_bytes(f.activations_bf16)]);
    t.row(trow!["model parameters (master)", "fp32", fmt_bytes(f.params_fp32)]);
    t.row(trow!["gradients (accum)", "fp32", fmt_bytes(f.grads_fp32)]);
    t.row(trow!["optimizer states (Adam)", "fp32", fmt_bytes(f.optimizer_fp32)]);
    t.row(trow!["TOTAL", "", fmt_bytes(f.total())]);
    println!(
        "Table I footprint — {} ({}), {} GPUs, B={}, C={}",
        model.name,
        model.params_label(),
        w.n_gpus,
        w.batch,
        w.context
    );
    print!("{}", t.render());
    let cfg = RunConfig::new(model, w, policy).with_schedule(schedule.clone());
    let built = if lifetime {
        MemoryPlan::build_lifetime_aware(&topo, &cfg)
    } else {
        MemoryPlan::build(&topo, &cfg)
    };
    match built {
        Ok(plan) => {
            // The tensor table wants profiles even under engines that don't
            // consume them for placement; reuse the plan's own pass when it
            // already ran one (lifetime mode / profile-aware engine).
            let profiles = match &plan.profiles {
                Some(p) => p.clone(),
                None => MemoryPlan::profile_run(&topo, &cfg).map_err(|e| anyhow!("{e}"))?,
            };
            println!();
            print!("{}", plan.alloc.describe());
            println!();
            println!(
                "tensor table (schedule {}, phases: {}):",
                schedule.name(),
                profiles.phases.join(" → ")
            );
            let mut tt = Table::new(&[
                "region",
                "class",
                "bytes",
                "H2D/iter",
                "D2H/iter",
                "RMW elems",
                "live",
                "placement",
            ])
            .left(0)
            .left(1)
            .left(7);
            for r in plan.alloc.regions() {
                let p = profiles.get(&r.name);
                let parts: Vec<String> = r
                    .placement
                    .parts
                    .iter()
                    .map(|(n, b)| format!("{}={}", topo.node(*n).name, fmt_bytes(*b)))
                    .collect();
                tt.row(trow![
                    r.name.clone(),
                    r.class.name(),
                    fmt_bytes(r.bytes),
                    p.map(|p| fmt_bytes(p.h2d_bytes as u64)).unwrap_or_else(|| "-".into()),
                    p.map(|p| fmt_bytes(p.d2h_bytes as u64)).unwrap_or_else(|| "-".into()),
                    p.map(|p| p.cpu_rmw_elements.to_string()).unwrap_or_else(|| "-".into()),
                    p.map(|p| p.lifetime.to_string()).unwrap_or_else(|| "-".into()),
                    parts.join(" + ")
                ]);
            }
            print!("{}", tt.render());
            if let Some(path) = a.get("json").filter(|s| !s.is_empty()) {
                let json = tensor_table_json(&topo, &cfg, &plan, &profiles, lifetime);
                std::fs::write(path, json.to_string_pretty())
                    .map_err(|e| anyhow!("writing {path}: {e}"))?;
                println!("wrote {path}");
            }
        }
        Err(e) => println!("\nplan does NOT fit: {e}"),
    }
    Ok(())
}

/// The machine-readable tensor table `plan --json` emits: one entry per
/// region with its measured profile, committed placement, and lifetime —
/// what sweeps and notebooks consume.
fn tensor_table_json(
    topo: &SystemTopology,
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
    profiles: &crate::offload::RunProfiles,
    lifetime: bool,
) -> crate::util::json::Json {
    use crate::jobj;
    use crate::util::json::Json;
    let phases: Vec<Json> = profiles.phases.iter().map(|p| Json::Str(p.clone())).collect();
    let regions: Vec<Json> = plan
        .alloc
        .regions()
        .map(|r| {
            let placement: Vec<Json> = r
                .placement
                .parts
                .iter()
                .map(|(n, b)| {
                    jobj! {
                        "node" => n.0,
                        "name" => topo.node(*n).name.as_str(),
                        "bytes" => *b,
                    }
                })
                .collect();
            let profile = match profiles.get(&r.name) {
                Some(p) => jobj! {
                    "h2d_bytes" => p.h2d_bytes,
                    "d2h_bytes" => p.d2h_bytes,
                    "cpu_rmw_elements" => p.cpu_rmw_elements,
                    "cpu_stream_bytes" => p.cpu_stream_bytes,
                    "touches" => p.touches as u64,
                    "birth_phase" => p.lifetime.birth_phase as u64,
                    "death_phase" => p.lifetime.death_phase as u64,
                },
                None => Json::Null,
            };
            let committed_lifetime = match r.lifetime {
                Some(l) => jobj! {
                    "birth_phase" => l.birth_phase as u64,
                    "death_phase" => l.death_phase as u64,
                },
                None => Json::Null,
            };
            jobj! {
                "name" => r.name.as_str(),
                "class" => r.class.name(),
                "bytes" => r.bytes,
                "profile" => profile,
                "lifetime" => committed_lifetime,
                "placement" => Json::Arr(placement),
            }
        })
        .collect();
    jobj! {
        "model" => cfg.model.name.as_str(),
        "policy" => cfg.engine.name(),
        "schedule" => cfg.schedule.name(),
        "topology" => topo.name.as_str(),
        "lifetime_accounting" => lifetime,
        "phases" => Json::Arr(phases),
        "regions" => Json::Arr(regions),
    }
}

pub fn simulate(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new("cxlfine simulate", "one-iteration phase breakdown")
        .opt("model", "12b", "model preset")
        .opt("preset", "config-a", "hardware preset")
        .opt("dram", "", "override DRAM capacity")
        .opt("gpus", "2", "number of GPUs")
        .opt("batch", "16", "per-GPU batch")
        .opt("context", "4096", "context length")
        .opt("policy", "cxl-aware", "placement policy")
        .opt(
            "schedule",
            "zero-offload",
            "fine-tuning schedule (zero-offload|grad-accum[:K]|lora[:R]|no-act-offload)",
        )
        .opt("prefetch", "2", "parameter prefetch depth (blocks)");
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), a.get("dram").filter(|s| !s.is_empty()))?;
    let model = get_model(a.get("model").unwrap())?;
    let policy = get_engine(a.get("policy").unwrap())?;
    let schedule = get_schedule(a.get("schedule").unwrap())?;
    let w = Workload::new(
        a.parse_usize("gpus")?,
        a.parse_usize("batch")?,
        a.parse_usize("context")?,
    );
    let mut cfg = RunConfig::new(model, w, policy.clone()).with_schedule(schedule.clone());
    cfg.prefetch_depth = a.parse_usize("prefetch")?;
    let plan = MemoryPlan::build(&topo, &cfg).map_err(|e| anyhow!("{e}"))?;
    let (report, _) = simulate_iteration_report(&topo, &cfg, &plan);
    let b = report.to_breakdown();
    let mut t = Table::new(&["phase", "seconds", "share"]).left(0);
    let (sf, sb, ss) = b.shares();
    t.row(trow!["FWD", fmt_secs(b.fwd_s), format!("{:.1}%", 100.0 * sf)]);
    t.row(trow!["BWD", fmt_secs(b.bwd_s), format!("{:.1}%", 100.0 * sb)]);
    t.row(trow!["STEP", fmt_secs(b.step_s), format!("{:.1}%", 100.0 * ss)]);
    t.row(trow!["iteration", fmt_secs(b.iter_s), "100%"]);
    println!(
        "policy {} × schedule {} on {}: {:.0} tokens/s",
        policy.name(),
        schedule.name(),
        topo.name,
        b.tokens_per_sec()
    );
    print!("{}", t.render());
    // Generalized phase extents: phases may overlap (grad accumulation
    // interleaves fwd/bwd windows), so extents are reported per phase
    // instead of pretending the triple above partitions the iteration.
    let mut te = Table::new(&["phase (extent)", "start", "end", "busy"]).left(0);
    for p in &report.phases {
        te.row(trow![
            p.name.clone(),
            fmt_secs(p.start_s),
            fmt_secs(p.end_s),
            fmt_secs(p.busy_s)
        ]);
    }
    print!("{}", te.render());
    Ok(())
}

pub fn sweep(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new("cxlfine sweep", "policy grid vs baseline (Fig. 9/10)")
        .opt("model", "7b", "model preset")
        .opt("preset", "config-a", "hardware preset for CXL runs")
        .opt("dram", "128GiB", "DRAM available to CXL policies")
        .opt("gpus", "1", "number of GPUs")
        .opt("contexts", "4096,8192,16384,32768", "comma list")
        .opt("batches", "1,4,16,32", "comma list")
        .opt(
            "ours",
            "",
            "engine for the 'ours' column (any registered policy, e.g. adaptive-spill or profile-aware)",
        )
        .opt(
            "schedule",
            "zero-offload",
            "comma list of fine-tuning schedules to sweep (engine × schedule matrix)",
        )
        .opt("json", "", "also write the full sweep (with digest) to this JSON file")
        .flag("striping", "use the striped CXL-aware policy as 'ours'")
        .flag(
            "no-cache",
            "evaluate through the legacy uncached path (bit-identical results, no memoization)",
        );
    let a = parse(spec, args)?;
    let base_topo = get_topo(a.get("preset").unwrap(), None)?;
    let cxl_topo = get_topo(a.get("preset").unwrap(), a.get("dram"))?;
    let model = get_model(a.get("model").unwrap())?;
    let gpus = a.parse_usize("gpus")?;
    let contexts: Vec<usize> = a
        .parse_count_list("contexts")?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let batches: Vec<usize> = a
        .parse_count_list("batches")?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let ours: EngineRef = match a.get("ours").filter(|s| !s.is_empty()) {
        Some(name) => {
            if a.flag("striping") {
                return Err(CliDone::Bad(
                    "--ours and --striping conflict: --striping selects cxl-aware+striping \
                     as the 'ours' column, --ours names an engine directly"
                        .to_string(),
                ));
            }
            get_engine(name)?
        }
        None => Policy::CxlAware {
            striping: a.flag("striping"),
        }
        .into(),
    };
    let schedules: Vec<ScheduleRef> = a
        .get("schedule")
        .unwrap()
        .split(',')
        .map(|s| get_schedule(s.trim()))
        .collect::<Result<_, _>>()?;
    let policies: Vec<EngineRef> =
        vec![Policy::DramOnly.into(), Policy::NaiveInterleave.into(), ours];
    let nthreads = crate::util::threadpool::default_threads();
    // Default path: the incremental engine (offload::evalcache) — memoized
    // probes/plans/schedules/DES runs, per-worker arenas, heaviest-cell-
    // first dispatch. --no-cache forces the legacy path; results are
    // bit-identical either way (same digest), only the work differs.
    let (res, cache_line) = if a.flag("no-cache") {
        let res = sweep_grid_matrix_nocache(
            &base_topo,
            &cxl_topo,
            &model,
            gpus,
            &contexts,
            &batches,
            &policies,
            &schedules,
            nthreads,
        );
        (res, None)
    } else {
        let ctx = EvalCtx::new();
        let res = sweep_grid_matrix_with_ctx(
            &ctx,
            &base_topo,
            &cxl_topo,
            &model,
            gpus,
            &contexts,
            &batches,
            &policies,
            &schedules,
            nthreads,
        );
        (res, Some(ctx.stats().summary_line()))
    };
    // Column 0 (DRAM baseline × first schedule) is the normalization root;
    // every other engine × schedule column reports % of it.
    let mut headers: Vec<String> = vec!["context".into(), "batch".into()];
    headers.push(format!("{} tok/s", res.policies[0]));
    for name in res.policies.iter().skip(1) {
        headers.push(format!("{name} %"));
    }
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&header_refs);
    for p in &res.points {
        let base = p.runs[0].as_ref();
        let mut row = vec![
            p.context.to_string(),
            p.batch.to_string(),
            base.map(|b| format!("{:.0}", b.tokens_per_sec()))
                .unwrap_or_else(|| "OOM".into()),
        ];
        for i in 1..res.policies.len() {
            row.push(match res.normalized(p, i, 0) {
                Some(r) => format!("{:.1}%", 100.0 * r),
                None => "OOM".into(),
            });
        }
        t.row(row);
    }
    println!(
        "{} × {} GPU(s) on {} (CXL policies get {} DRAM)",
        model.name,
        gpus,
        base_topo.name,
        a.get("dram").unwrap()
    );
    print!("{}", t.render());
    for i in 1..res.policies.len() {
        if let Some((lo, hi)) = res.normalized_range(i, 0) {
            println!(
                "{:<28} range: {:.0}%–{:.0}%",
                res.policies[i],
                lo * 100.0,
                hi * 100.0
            );
        }
    }
    if let Some(line) = cache_line {
        println!("{line}");
    }
    if let Some(path) = a.get("json").filter(|s| !s.is_empty()) {
        std::fs::write(path, res.to_json().to_string_pretty())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

pub fn optimizer(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new(
        "cxlfine optimizer",
        "Adam step time vs elements: simulated DRAM/CXL + real measured (this host)",
    )
    .opt("elements", "1m,5m,20m,50m,100m,200m", "element counts")
    .opt("preset", "config-a", "hardware preset for the simulated lines")
    .flag("measure", "also run the real Rust Adam on this machine");
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), None)?;
    let mm = OptimizerMemModel::new(&topo);
    let cxl = topo.cxl_nodes()[0];
    let mut t = Table::new(&["elements", "sim DRAM", "sim CXL", "ratio", "measured (host)"]);
    for n in a.parse_count_list("elements")? {
        let td = mm.step_time(n, &OptLayout::dram_only());
        let tc = mm.step_time(n, &OptLayout::single_node(cxl));
        let measured = if a.flag("measure") && n <= 200_000_000 {
            let mut p = vec![1.0f32; n as usize];
            let g = vec![0.5f32; n as usize];
            let mut st = AdamState::new(n as usize);
            let t0 = std::time::Instant::now();
            adam_step(&mut p, &g, &mut st, &AdamHp::default(), crate::util::threadpool::default_threads());
            fmt_secs(t0.elapsed().as_secs_f64())
        } else {
            "-".into()
        };
        t.row(trow![n, fmt_secs(td), fmt_secs(tc), format!("{:.2}x", tc / td), measured]);
    }
    print!("{}", t.render());
    Ok(())
}

pub fn bandwidth(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new("cxlfine bandwidth", "host→GPU DMA bandwidth (Fig. 6)")
        .opt("preset", "config-a", "hardware preset")
        .opt("sizes", "64k,1m,16m,256m,1000m", "transfer sizes (bytes)")
        .opt("gpus", "2", "concurrent GPUs");
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), None)?;
    let n_gpus = a.parse_usize("gpus")?.min(topo.gpus.len());
    let cxl = topo.cxl_nodes()[0];
    let mut t = Table::new(&["size", "DRAM 1 GPU", "CXL 1 GPU", &format!("DRAM {n_gpus} GPUs (agg)"), &format!("CXL {n_gpus} GPUs (agg)")]);
    for size in a.parse_count_list("sizes")? {
        let size = size as f64;
        let single = |node: NodeId| {
            let mut fab = Fabric::new(&topo);
            let f = fab.transfer(GpuId(0), node, Dir::HostToGpu, size, 0);
            fab.sim.run_to_idle();
            fab.sim.stats(f).unwrap().e2e_throughput()
        };
        let multi = |node: NodeId| {
            let mut fab = Fabric::new(&topo);
            for g in 0..n_gpus {
                fab.transfer(GpuId(g), node, Dir::HostToGpu, size, g as u64);
            }
            fab.sim.run_to_idle();
            n_gpus as f64 * size / fab.now()
        };
        t.row(trow![
            fmt_bytes(size as u64),
            fmt_rate(single(NodeId(0))),
            fmt_rate(single(cxl)),
            fmt_rate(multi(NodeId(0))),
            fmt_rate(multi(cxl))
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

pub fn train(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new("cxlfine train", "functional fine-tuning on AOT artifacts")
        .opt("artifacts", "artifacts", "artifact directory (make artifacts)")
        .opt("steps", "200", "training steps")
        .opt("lr", "0.003", "learning rate")
        .opt("log-every", "10", "log interval")
        .opt("out", "", "write loss curve CSV here");
    let a = parse(spec, args)?;
    let rt = crate::runtime::Runtime::load(a.get("artifacts").unwrap())?;
    let (b, c) = crate::train::batch_shape(&rt)?;
    let cfg = crate::train::TrainerCfg {
        batch: b,
        context: c,
        steps: a.parse_usize("steps")?,
        hp: AdamHp {
            lr: a.parse_f64("lr")? as f32,
            ..Default::default()
        },
        log_every: a.parse_usize("log-every")?,
        ..Default::default()
    };
    println!(
        "training {} params on {} (B={b}, C={c})",
        rt.manifest().meta_usize("n_params").unwrap_or(0),
        rt.platform()
    );
    let mut trainer = crate::train::Trainer::new(&rt, cfg)?;
    let logs = trainer.train()?;
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    println!("loss: {first:.4} → {last:.4} over {} steps", logs.len());
    if let Some(path) = a.get("out").filter(|s| !s.is_empty()) {
        let mut csv = String::from("step,loss,wall_s\n");
        for l in &logs {
            csv.push_str(&format!("{},{},{}\n", l.step, l.loss, l.wall_s));
        }
        std::fs::write(path, csv).map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    let _ = GIB;
    Ok(())
}

/// `cxlfine fleet` — multi-tenant job scheduling on one shared host.
pub fn fleet(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new(
        "cxlfine fleet",
        "multi-tenant fleet simulation: job scheduling + online DRAM/CXL capacity management",
    )
    .opt("preset", "config-a", "hardware preset of the shared host")
    .opt("dram", "128GiB", "DRAM capacity of the shared host")
    .opt(
        "policy",
        "placement-aware",
        "admission policy (fifo|backfill|placement-aware)",
    )
    .opt(
        "engine",
        "cxl-aware+striping",
        "placement engine generated jobs request",
    )
    .opt("jobs", "100", "jobs to generate when no trace file is replayed")
    .opt("seed", "42", "trace-generator seed")
    .opt("rate", "120", "mean inter-arrival seconds of the Poisson arrivals")
    .opt(
        "trace",
        "",
        "trace JSON path: replay it if the file exists, else generate and save there",
    )
    .opt(
        "faults",
        "",
        "fault-trace JSON path: replay it if the file exists, else generate and save there",
    )
    .opt(
        "recovery",
        "fail-stop",
        "recovery policy for fault-hit jobs (fail-stop|checkpoint-restart|evacuate)",
    )
    .opt("fault-seed", "1", "fault-generator seed")
    .opt("n-faults", "4", "fault events to generate when no fault trace is replayed")
    .opt(
        "json",
        "",
        "write the full result (per-job records + occupancy, digest-self-certifying) here",
    )
    .opt("threads", "0", "calibration worker threads (0 = default)");
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), a.get("dram").filter(|s| !s.is_empty()))?;
    let policy_name = a.get("policy").unwrap();
    let policy = fleet::scheduler::by_name(policy_name).ok_or_else(|| {
        CliDone::Bad(format!(
            "unknown policy {policy_name:?} ({})",
            fleet::scheduler::known_names().join("|")
        ))
    })?;
    let engine_name = a.get("engine").unwrap().to_string();
    get_engine(&engine_name)?; // validate the name up front
    let trace_path = a.get("trace").filter(|s| !s.is_empty()).map(str::to_string);
    let trace = match trace_path
        .as_deref()
        .filter(|p| std::path::Path::new(p).exists())
    {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| anyhow!("reading {p}: {e}"))?;
            let json =
                crate::util::json::Json::parse(&text).map_err(|e| anyhow!("parsing {p}: {e}"))?;
            let t = FleetTrace::from_json(&json).map_err(|e| anyhow!("{p}: {e}"))?;
            println!(
                "replaying {} jobs from {p} (generation flags --jobs/--seed/--rate/--engine \
                 are ignored on replay; delete the file to regenerate)",
                t.jobs.len()
            );
            t
        }
        None => {
            let rate = a.parse_f64("rate")?;
            if !(rate.is_finite() && rate > 0.0) {
                return Err(CliDone::Bad(format!(
                    "--rate must be a positive number of seconds, got {rate}"
                )));
            }
            let mut tg = TraceGen::mixed(a.parse_u64("seed")?, a.parse_usize("jobs")?);
            tg.mean_interarrival_s = rate;
            tg.engines = vec![engine_name];
            let t = tg.generate();
            if let Some(p) = &trace_path {
                std::fs::write(p, t.to_json().to_string_pretty())
                    .map_err(|e| anyhow!("writing {p}: {e}"))?;
                println!("wrote generated trace to {p}");
            }
            t
        }
    };
    let recovery_name = a.get("recovery").unwrap();
    let recovery = fleet::faults::by_name(recovery_name).ok_or_else(|| {
        CliDone::Bad(format!(
            "unknown recovery policy {recovery_name:?} ({})",
            fleet::faults::known_names().join("|")
        ))
    })?;
    let faults_path = a.get("faults").filter(|s| !s.is_empty()).map(str::to_string);
    let faults = match faults_path
        .as_deref()
        .filter(|p| std::path::Path::new(p).exists())
    {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| anyhow!("reading {p}: {e}"))?;
            let json =
                crate::util::json::Json::parse(&text).map_err(|e| anyhow!("parsing {p}: {e}"))?;
            let f = FaultTrace::from_json(&json).map_err(|e| anyhow!("{p}: {e}"))?;
            f.validate(&topo).map_err(|e| anyhow!("{p}: {e}"))?;
            println!(
                "replaying {} fault events from {p} (--fault-seed/--n-faults are ignored \
                 on replay; delete the file to regenerate)",
                f.events.len()
            );
            f
        }
        None => match &faults_path {
            Some(p) => {
                let horizon =
                    trace.jobs.last().map(|j| j.arrival_s).unwrap_or(0.0).max(1.0);
                let f = fleet::FaultGen::new(
                    a.parse_u64("fault-seed")?,
                    a.parse_usize("n-faults")?,
                    horizon,
                )
                .generate(&topo);
                std::fs::write(p, f.to_json().to_string_pretty())
                    .map_err(|e| anyhow!("writing {p}: {e}"))?;
                println!("wrote generated fault trace to {p}");
                f
            }
            None => FaultTrace::empty(),
        },
    };
    let threads = match a.parse_usize("threads")? {
        0 => crate::util::threadpool::default_threads(),
        n => n,
    };
    let res = simulate_fleet_faulted(&topo, &trace, &policy, &faults, &recovery, threads);
    println!(
        "fleet of {} jobs under {} on {} (digest {:016x})",
        trace.jobs.len(),
        res.policy,
        topo.name,
        res.digest()
    );
    if !faults.events.is_empty() {
        println!(
            "injected {} fault events (digest {:016x}) under {} recovery",
            faults.events.len(),
            faults.digest(),
            res.recovery
        );
    }
    print!("{}", res.summary_table().render());
    println!();
    print!("{}", res.occupancy_table().render());
    if let Some(rt) = res.reasons_table() {
        println!();
        print!("{}", rt.render());
    }
    if let Some(path) = a.get("json").filter(|s| !s.is_empty()) {
        std::fs::write(path, res.to_json().to_string_pretty())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `cxlfine serve` — request-level inference on a CXL-tiered KV cache.
pub fn serve(args: &[String]) -> Result<(), CliDone> {
    use crate::serve::{self, RequestGen, RequestTrace};
    let spec = CliSpec::new(
        "cxlfine serve",
        "request-level inference serving: continuous batching over a CXL-tiered paged KV cache",
    )
    .opt("preset", "config-a", "hardware preset of the serving host")
    .opt("dram", "", "override DRAM capacity, e.g. 64GiB")
    .opt(
        "model",
        "7b",
        "model preset every request runs (one resident model per host)",
    )
    .opt(
        "kv-policy",
        "tiered",
        "KV cache policy (dram-only|tiered[:H]; 'ours' = the tiered default)",
    )
    .opt("policy", "slo-strict", "admission policy (fcfs|slo-strict)")
    .opt(
        "requests",
        "50",
        "requests to generate when no trace file is replayed",
    )
    .opt("seed", "42", "trace-generator seed")
    .opt("rate", "2", "mean inter-arrival seconds of the Poisson arrivals")
    .opt("slo-ms", "30000", "TTFT SLO stamped on generated requests")
    .opt("max-batch", "8", "continuous-batching slot count")
    .opt(
        "trace",
        "",
        "trace JSON path: replay it if the file exists, else generate and save there",
    )
    .opt(
        "json",
        "",
        "write the full result (per-request records + occupancy, digest-self-certifying) here",
    )
    .opt("threads", "0", "calibration worker threads (0 = default)");
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), a.get("dram").filter(|s| !s.is_empty()))?;
    let model_name = a.get("model").unwrap();
    get_model(model_name)?; // validate the name up front
    let kv_name = a.get("kv-policy").unwrap();
    let kv = serve::kv::by_name(kv_name).ok_or_else(|| {
        CliDone::Bad(format!(
            "unknown KV policy {kv_name:?} ({})",
            serve::kv::known_names().join("|")
        ))
    })?;
    let adm_name = a.get("policy").unwrap();
    let adm = serve::admission_by_name(adm_name).ok_or_else(|| {
        CliDone::Bad(format!(
            "unknown admission policy {adm_name:?} ({})",
            serve::admission_known_names().join("|")
        ))
    })?;
    let max_batch = a.parse_usize("max-batch")?;
    if max_batch == 0 {
        return Err(CliDone::Bad("--max-batch must be at least 1".into()));
    }
    let trace_path = a.get("trace").filter(|s| !s.is_empty()).map(str::to_string);
    let trace = match trace_path
        .as_deref()
        .filter(|p| std::path::Path::new(p).exists())
    {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| anyhow!("reading {p}: {e}"))?;
            let json =
                crate::util::json::Json::parse(&text).map_err(|e| anyhow!("parsing {p}: {e}"))?;
            let t = RequestTrace::from_json(&json).map_err(|e| anyhow!("{p}: {e}"))?;
            println!(
                "replaying {} requests from {p} (generation flags --requests/--seed/--rate/\
                 --slo-ms/--model are ignored on replay; delete the file to regenerate)",
                t.requests.len()
            );
            t
        }
        None => {
            let rate = a.parse_f64("rate")?;
            if !(rate.is_finite() && rate > 0.0) {
                return Err(CliDone::Bad(format!(
                    "--rate must be a positive number of seconds, got {rate}"
                )));
            }
            let slo_ms = a.parse_f64("slo-ms")?;
            if !(slo_ms.is_finite() && slo_ms > 0.0) {
                return Err(CliDone::Bad(format!(
                    "--slo-ms must be a positive number of milliseconds, got {slo_ms}"
                )));
            }
            let mut rg =
                RequestGen::mixed(a.parse_u64("seed")?, a.parse_usize("requests")?, model_name);
            rg.mean_interarrival_s = rate;
            rg.slo_ms = slo_ms;
            let t = rg.generate();
            if let Some(p) = &trace_path {
                std::fs::write(p, t.to_json().to_string_pretty())
                    .map_err(|e| anyhow!("writing {p}: {e}"))?;
                println!("wrote generated trace to {p}");
            }
            t
        }
    };
    let threads = match a.parse_usize("threads")? {
        0 => crate::util::threadpool::default_threads(),
        n => n,
    };
    let res = serve::simulate_serving(&topo, &trace, &kv, &adm, max_batch, threads);
    println!(
        "served {} requests under {} + {} on {} (digest {:016x})",
        trace.requests.len(),
        res.kv_policy,
        res.admission,
        topo.name,
        res.digest()
    );
    print!("{}", res.summary_table().render());
    println!();
    print!("{}", res.occupancy_table().render());
    if let Some(rt) = res.reasons_table() {
        println!();
        print!("{}", rt.render());
    }
    if let Some(path) = a.get("json").filter(|s| !s.is_empty()) {
        std::fs::write(path, res.to_json().to_string_pretty())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `cxlfine lint` — run the static verifier over schedules / plans / traces.
///
/// Sweeps every registered schedule (or one, with `--schedule`) across the
/// requested model × preset grid: builds a lifetime-aware plan, builds the
/// schedule against it, and runs [`crate::analysis::lint_schedule`] (with
/// the plan's region context) plus [`crate::analysis::lint_plan`]. With
/// `--trace` it also lints a fleet-trace JSON file. Exit is nonzero on any
/// Error diagnostic, or on Warnings under `--deny-warnings`; Infos never
/// fail the run. The JSON report is written before the exit verdict so CI
/// can upload it from a failing job.
pub fn lint(args: &[String]) -> Result<(), CliDone> {
    use crate::analysis::{self, Severity};
    use crate::jobj;
    use crate::util::json::Json;
    let spec = CliSpec::new(
        "cxlfine lint",
        "static verifier for schedules, memory plans, and fleet traces",
    )
    .opt("schedule", "", "lint one registered schedule (default: all)")
    .flag("all", "lint every registered schedule (the default when --schedule is empty)")
    .opt("model", "7b,tiny-2m", "comma-separated model presets to sweep")
    .opt("preset", "config-a,config-b", "comma-separated hardware presets to sweep")
    .opt("dram", "", "override DRAM capacity on every preset")
    .opt("gpus", "1", "number of GPUs")
    .opt("batch", "4", "per-GPU batch size")
    .opt("context", "4096", "context length (tokens)")
    .opt(
        "trace",
        "",
        "also lint this fleet-trace, fault-trace, or request-trace JSON file (P2xx codes; \
         request traces are detected by their 'requests' array, fault traces by their \
         'events' array — the latter checked against the first --preset)",
    )
    .opt("json", "", "write the full diagnostic report to this JSON file")
    .flag("deny-warnings", "treat Warn diagnostics as fatal (CI mode)");
    let a = parse(spec, args)?;
    let deny = a.flag("deny-warnings");
    let schedules: Vec<ScheduleRef> = match a.get("schedule").filter(|s| !s.is_empty()) {
        Some(name) => vec![get_schedule(name)?],
        None => schedules::registered(),
    };
    let models: Vec<&str> =
        a.get("model").unwrap().split(',').filter(|s| !s.is_empty()).collect();
    let presets: Vec<&str> =
        a.get("preset").unwrap().split(',').filter(|s| !s.is_empty()).collect();
    let dram = a.get("dram").filter(|s| !s.is_empty());
    let w = Workload::new(
        a.parse_usize("gpus")?,
        a.parse_usize("batch")?,
        a.parse_usize("context")?,
    );
    let engine = get_engine("cxl-aware+striping")?;

    let (mut n_err, mut n_warn, mut n_info) = (0usize, 0usize, 0usize);
    let mut cells: Vec<Json> = Vec::new();
    let mut detail: Vec<String> = Vec::new();
    let mut t =
        Table::new(&["schedule", "model", "preset", "errors", "warnings", "infos", "verdict"])
            .left(0)
            .left(1)
            .left(2)
            .left(6);
    for sref in &schedules {
        for model_name in &models {
            let model = get_model(model_name)?;
            for preset_name in &presets {
                let topo = get_topo(preset_name, dram)?;
                let cfg = RunConfig::new(model.clone(), w, engine.clone())
                    .with_schedule(sref.clone());
                let cell = format!("{} × {} × {}", sref.name(), model_name, preset_name);
                let mut diags = analysis::Diagnostics::new();
                let mut verdict;
                match MemoryPlan::build_lifetime_aware(&topo, &cfg) {
                    Ok(plan) => {
                        let sched = cfg.schedule.build(&topo, &cfg, &plan);
                        let ctx = analysis::ScheduleLintContext::from_plan(&plan);
                        diags.extend(analysis::lint_schedule(&sched, &topo, Some(&ctx)));
                        diags.extend(analysis::lint_plan(&plan));
                        verdict = if diags.has_errors() {
                            "FAIL"
                        } else if diags.has_warnings() {
                            "warn"
                        } else {
                            "clean"
                        };
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        if msg.contains("static lint") {
                            // The builder's own lint gate fired: surface it.
                            diags.push(
                                "P000",
                                Severity::Error,
                                analysis::Anchor::General,
                                msg,
                            );
                            verdict = "FAIL";
                        } else {
                            // Capacity outcome, not a defect in the IRs.
                            verdict = "no-fit";
                        }
                    }
                }
                n_err += diags.count(Severity::Error);
                n_warn += diags.count(Severity::Warn);
                n_info += diags.count(Severity::Info);
                if deny && verdict == "warn" {
                    verdict = "FAIL";
                }
                for d in &diags {
                    detail.push(format!("{cell}: {}", d.render()));
                }
                let dj: Vec<Json> = diags.iter().map(|d| d.to_json()).collect();
                cells.push(jobj! {
                    "schedule" => sref.name(),
                    "model" => *model_name,
                    "preset" => *preset_name,
                    "verdict" => verdict,
                    "diagnostics" => Json::Arr(dj),
                });
                t.row(trow![
                    sref.name(),
                    *model_name,
                    *preset_name,
                    diags.count(Severity::Error),
                    diags.count(Severity::Warn),
                    diags.count(Severity::Info),
                    verdict
                ]);
            }
        }
    }

    let mut trace_json = Json::Null;
    if let Some(path) = a.get("trace").filter(|s| !s.is_empty()) {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        // A request trace carries 'requests', a fault trace 'events', a
        // fleet trace 'jobs'.
        let diags = if json.path(&["requests"]).is_some() {
            analysis::lint_request_trace(&json)
        } else if json.path(&["events"]).is_some() {
            let topo = get_topo(presets.first().copied().unwrap_or("config-a"), dram)?;
            analysis::lint_fault_trace(&json, Some(&topo))
        } else {
            analysis::lint_trace(&json)
        };
        n_err += diags.count(Severity::Error);
        n_warn += diags.count(Severity::Warn);
        n_info += diags.count(Severity::Info);
        for d in &diags {
            detail.push(format!("{path}: {}", d.render()));
        }
        let dj: Vec<Json> = diags.iter().map(|d| d.to_json()).collect();
        trace_json = jobj! {
            "path" => path,
            "diagnostics" => Json::Arr(dj),
        };
    }

    println!(
        "lint: {} schedule(s) × {} model(s) × {} preset(s)",
        schedules.len(),
        models.len(),
        presets.len()
    );
    print!("{}", t.render());
    if !detail.is_empty() {
        println!();
        for line in &detail {
            println!("{line}");
        }
    }
    println!();
    println!("{n_err} error(s), {n_warn} warning(s), {n_info} info(s)");

    if let Some(path) = a.get("json").filter(|s| !s.is_empty()) {
        let report = jobj! {
            "deny_warnings" => deny,
            "errors" => n_err as u64,
            "warnings" => n_warn as u64,
            "infos" => n_info as u64,
            "cells" => Json::Arr(cells),
            "trace" => trace_json,
        };
        std::fs::write(path, report.to_string_pretty())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }

    if n_err > 0 {
        return Err(CliDone::Runtime(anyhow!("lint found {n_err} error(s)")));
    }
    if deny && n_warn > 0 {
        return Err(CliDone::Runtime(anyhow!(
            "lint found {n_warn} warning(s) under --deny-warnings"
        )));
    }
    Ok(())
}

/// `cxlfine trace` — export a Chrome-trace of one simulated iteration.
pub fn trace(args: &[String]) -> Result<(), CliDone> {
    let spec = CliSpec::new(
        "cxlfine trace",
        "export a chrome://tracing JSON of one simulated iteration",
    )
    .opt("model", "12b", "model preset")
    .opt("preset", "config-a", "hardware preset")
    .opt("dram", "", "override DRAM capacity")
    .opt("gpus", "2", "number of GPUs")
    .opt("batch", "16", "per-GPU batch")
    .opt("context", "4096", "context length")
    .opt("policy", "cxl-aware", "placement policy")
    .opt(
        "schedule",
        "zero-offload",
        "fine-tuning schedule (zero-offload|grad-accum[:K]|lora[:R]|no-act-offload)",
    )
    .opt("out", "trace.json", "output path");
    let a = parse(spec, args)?;
    let topo = get_topo(a.get("preset").unwrap(), a.get("dram").filter(|s| !s.is_empty()))?;
    let model = get_model(a.get("model").unwrap())?;
    let policy = get_engine(a.get("policy").unwrap())?;
    let schedule = get_schedule(a.get("schedule").unwrap())?;
    let w = Workload::new(
        a.parse_usize("gpus")?,
        a.parse_usize("batch")?,
        a.parse_usize("context")?,
    );
    let cfg = RunConfig::new(model, w, policy).with_schedule(schedule);
    let plan = MemoryPlan::build(&topo, &cfg).map_err(|e| anyhow!("{e}"))?;
    let (bd, trace) = crate::offload::simulate_iteration_traced(&topo, &cfg, &plan);
    let out = a.get("out").unwrap();
    std::fs::write(out, trace.to_chrome_trace().to_string_pretty())
        .map_err(|e| anyhow!("writing {out}: {e}"))?;
    println!(
        "wrote {} spans to {out} (iteration {:.2}s: FWD {:.2}s BWD {:.2}s STEP {:.2}s)",
        trace.spans().len(),
        bd.iter_s,
        bd.fwd_s,
        bd.bwd_s,
        bd.step_s
    );
    println!("open chrome://tracing or https://ui.perfetto.dev and load the file");
    for (lane, busy) in trace.lane_busy() {
        println!("  lane {lane:<14} busy {:.2}s ({:.0}%)", busy, 100.0 * busy / bd.iter_s);
    }
    Ok(())
}
