//! Static-verifier acceptance tests (ISSUE 6).
//!
//! * The clean bill: every registered schedule × both hardware configs
//!   lints with zero errors and zero warnings (the `lint --all
//!   --deny-warnings` CI gate, in-process).
//! * proptest_lite mutation drills: seeded corruptions of a known-clean
//!   schedule (dropped Dma touch, narrowed lifetime window, orphan node,
//!   dangling region id) must each fire their documented P0xx code.
//! * Plan/commit drills: an over-capacity phase peak fires P104 before
//!   commit.
//! * Trace drills: corrupted digest (P201), duplicate ids (P202),
//!   non-monotonic arrivals (P203), unregistered names (P204).
//! * Fault-trace drills: bad targets / DRAM offline (P207), unsorted
//!   times (P208), unpaired offline/restore (P209).
//! * Request-trace drills (ISSUE 10): non-monotonic arrivals (P210),
//!   non-positive tokens/SLO (P211), digest mismatch (P212), plus the
//!   shared P202/P204/P205/P206 shapes.

use cxlfine::analysis::{
    lint_commit, lint_fault_trace, lint_plan, lint_request_trace, lint_schedule, lint_trace,
    ScheduleLintContext, Severity,
};
use cxlfine::fleet::{FaultEvent, FaultGen, FaultKind, FaultTrace, TraceGen};
use cxlfine::serve::RequestGen;
use cxlfine::mem::{Lifetime, NumaAllocator, Placement, Policy, RegionRequest, TensorClass};
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets;
use cxlfine::offload::{schedules, FlopsTerm, MemoryPlan, Op, OpNode, RegionTouch, Schedule};
use cxlfine::topology::presets::{config_a, config_b, dev_tiny, with_dram_capacity};
use cxlfine::topology::{GpuId, NodeId, SystemTopology};
use cxlfine::util::proptest_lite::{forall, UsizeRange};
use cxlfine::util::units::GIB;

/// Build the known-clean fixture: zero-offload on the dev machine with the
/// 2M-parameter model, lifetime-aware placement. Returns the built schedule
/// plus the plan's region context (the same pair `lint --all` sweeps).
fn clean_setup(topo: &SystemTopology) -> (Schedule, ScheduleLintContext) {
    let cfg = cxlfine::offload::RunConfig::new(
        presets::tiny_2m(),
        Workload::new(1, 2, 256),
        Policy::CxlAware { striping: true },
    )
    .with_schedule(schedules::by_name("zero-offload").unwrap());
    let plan = MemoryPlan::build_lifetime_aware(topo, &cfg).expect("tiny plan fits dev machine");
    let sched = cfg.schedule.build(topo, &cfg, &plan);
    let ctx = ScheduleLintContext::from_plan(&plan);
    (sched, ctx)
}

fn dev_topo() -> SystemTopology {
    with_dram_capacity(dev_tiny(), 8 * GIB)
}

#[test]
fn fixture_is_clean() {
    let topo = dev_topo();
    let (sched, ctx) = clean_setup(&topo);
    let d = lint_schedule(&sched, &topo, Some(&ctx));
    assert!(!d.has_errors() && !d.has_warnings(), "fixture must lint clean:\n{}", d.render());
}

/// The CI gate, in-process: every registered schedule × config-a AND
/// config-b, lifetime-aware plans, zero errors and zero warnings.
#[test]
fn clean_bill_every_registered_schedule_on_both_configs() {
    for make_topo in [config_a, config_b] {
        let topo = with_dram_capacity(make_topo(), 128 * GIB);
        for sref in schedules::registered() {
            let cfg = cxlfine::offload::RunConfig::new(
                presets::qwen25_7b(),
                Workload::new(1, 4, 4096),
                Policy::CxlAware { striping: true },
            )
            .with_schedule(sref.clone());
            let plan = MemoryPlan::build_lifetime_aware(&topo, &cfg)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", sref.name(), topo.name));
            let sched = cfg.schedule.build(&topo, &cfg, &plan);
            let ctx = ScheduleLintContext::from_plan(&plan);
            let mut d = lint_schedule(&sched, &topo, Some(&ctx));
            d.extend(lint_plan(&plan));
            assert!(
                !d.has_errors() && !d.has_warnings(),
                "{} on {} must lint clean under --deny-warnings:\n{}",
                sref.name(),
                topo.name,
                d.render()
            );
        }
    }
}

/// Dropping the Dma touch from a transfer makes its traffic invisible to
/// profiling — the dishonest-touch drill must fire P009 on every pick.
#[test]
fn mutation_dropped_dma_touch_fires_p009() {
    let topo = dev_topo();
    forall("drop-dma-touch", 0x15EED, 16, &UsizeRange { lo: 0, hi: 1 << 20 }, |&pick| {
        let (mut sched, ctx) = clean_setup(&topo);
        let candidates: Vec<usize> = sched
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                matches!(n.op, Op::Transfer { bytes, .. } if bytes > 0.0)
                    && n.touches.iter().any(|t| matches!(t, RegionTouch::Dma(_)))
            })
            .map(|(i, _)| i)
            .collect();
        if candidates.is_empty() {
            return Err("fixture has no honest transfers to corrupt".into());
        }
        let victim = candidates[pick % candidates.len()];
        sched.nodes[victim].touches.retain(|t| !matches!(t, RegionTouch::Dma(_)));
        let d = lint_schedule(&sched, &topo, Some(&ctx));
        if !d.has_code("P009") {
            return Err(format!(
                "dropping node {victim}'s Dma touch must fire P009:\n{}",
                d.render()
            ));
        }
        Ok(())
    });
}

/// Narrowing a committed lifetime window under a region that is touched in
/// a later phase is an out-of-window access — P008, an error.
#[test]
fn mutation_narrowed_lifetime_window_fires_p008() {
    let topo = dev_topo();
    forall("narrow-lifetime", 0xBEEF, 16, &UsizeRange { lo: 0, hi: 1 << 20 }, |&pick| {
        let (sched, mut ctx) = clean_setup(&topo);
        // Regions touched at phase > 0: narrowing their window to [0] must
        // put that access outside the committed lifetime.
        let candidates: Vec<cxlfine::mem::RegionId> = sched
            .nodes
            .iter()
            .filter(|n| n.phase > 0)
            .flat_map(|n| n.touches.iter().map(|t| t.region()))
            .collect();
        if candidates.is_empty() {
            return Err("fixture touches nothing after phase 0".into());
        }
        let victim = candidates[pick % candidates.len()];
        for r in &mut ctx.regions {
            if r.id == victim {
                r.lifetime = Some(Lifetime::spanning(0, 0));
            }
        }
        let d = lint_schedule(&sched, &topo, Some(&ctx));
        if !d.has_code("P008") {
            return Err(format!(
                "narrowing region {victim:?} to [0] must fire P008:\n{}",
                d.render()
            ));
        }
        Ok(())
    });
}

/// An orphan node — no deps, no dependents — is dead scheduling weight;
/// P012 must flag it wherever it lands.
#[test]
fn mutation_orphan_node_fires_p012() {
    let topo = dev_topo();
    forall("orphan-node", 0x0B0E, 16, &UsizeRange { lo: 0, hi: 1 << 20 }, |&pick| {
        let (mut sched, ctx) = clean_setup(&topo);
        let phase = pick % sched.phases.len();
        sched.nodes.push(OpNode {
            op: Op::Compute { gpu: GpuId(0), work: vec![FlopsTerm::new(1e9)] },
            deps: Vec::new(),
            name: "orphan".into(),
            lane: "gpu0/compute".into(),
            phase,
            ends_phase: false,
            touches: Vec::new(),
        });
        let d = lint_schedule(&sched, &topo, Some(&ctx));
        if !d.has_code("P012") {
            return Err(format!("an orphan node in phase {phase} must fire P012:\n{}", d.render()));
        }
        Ok(())
    });
}

/// A touch naming a region the plan never committed is a dangling id —
/// P007, an error.
#[test]
fn mutation_dangling_region_id_fires_p007() {
    let topo = dev_topo();
    let (mut sched, ctx) = clean_setup(&topo);
    let victim = sched
        .nodes
        .iter()
        .position(|n| matches!(n.op, Op::Transfer { .. }))
        .expect("fixture has transfers");
    sched.nodes[victim]
        .touches
        .push(RegionTouch::Dma(cxlfine::mem::RegionId(9999)));
    let d = lint_schedule(&sched, &topo, Some(&ctx));
    assert!(d.has_code("P007"), "dangling RegionId(9999) must fire P007:\n{}", d.render());
    assert!(d.has_errors());
}

/// Committing a region whose bytes push a node past capacity in some phase
/// must be flagged by the pre-commit lint (P104) — the same arithmetic the
/// allocator's own commit check runs.
#[test]
fn over_capacity_phase_peak_fires_p104() {
    let topo = dev_topo();
    let alloc = NumaAllocator::new(&topo, Policy::CxlAware { striping: false });
    let cap = topo.node(NodeId(0)).capacity;
    let req = RegionRequest::new("too-big", TensorClass::Activations, cap + 1);
    let placement = Placement::single(NodeId(0), cap + 1);
    let d = lint_commit(&alloc, &req, &placement);
    assert!(d.has_code("P104"), "oversized commit must fire P104:\n{}", d.render());
    assert_eq!(d.count(Severity::Error), d.len(), "P104 is an error");

    // A placement that fits is silent.
    let ok = RegionRequest::new("fits", TensorClass::Activations, cap / 2);
    let d2 = lint_commit(&alloc, &ok, &Placement::single(NodeId(0), cap / 2));
    assert!(d2.is_empty(), "in-capacity commit must lint clean:\n{}", d2.render());
}

/// A malformed placement (bytes mismatch) is P101 at the same gate.
#[test]
fn malformed_placement_fires_p101() {
    let topo = dev_topo();
    let alloc = NumaAllocator::new(&topo, Policy::CxlAware { striping: false });
    let req = RegionRequest::new("r", TensorClass::Params16, 100);
    let d = lint_commit(&alloc, &req, &Placement::single(NodeId(0), 99));
    assert!(d.has_code("P101"), "bytes mismatch must fire P101:\n{}", d.render());
}

/// Trace drills: each corruption of a generated (clean) trace fires its
/// documented P2xx code.
#[test]
fn trace_corruptions_fire_their_codes() {
    let clean = TraceGen::mixed(9, 12).generate();
    let d = lint_trace(&clean.to_json());
    assert!(
        !d.has_errors() && !d.has_warnings(),
        "generated trace must lint clean:\n{}",
        d.render()
    );

    // P201: digest field says one thing, contents hash to another.
    let mut j = clean.to_json();
    if let cxlfine::util::json::Json::Obj(o) = &mut j {
        o.set("digest", "deadbeefdeadbeef");
    }
    let d = lint_trace(&j);
    assert!(d.has_code("P201"), "corrupted digest must fire P201:\n{}", d.render());

    // P202: duplicate job ids.
    let mut t = clean.clone();
    let id0 = t.jobs[0].id;
    t.jobs[1].id = id0;
    let d = lint_trace(&t.to_json());
    assert!(d.has_code("P202"), "duplicate ids must fire P202:\n{}", d.render());

    // P203: arrivals out of order (a warning, not an error).
    let mut t = clean.clone();
    let last = t.jobs.len() - 1;
    t.jobs[last].arrival_s = 0.0;
    let d = lint_trace(&t.to_json());
    assert!(d.has_code("P203"), "inverted arrivals must fire P203:\n{}", d.render());
    assert!(!d.has_errors(), "P203 is a warning:\n{}", d.render());

    // P204: names that resolve in no registry.
    let mut t = clean.clone();
    t.jobs[0].model = "no-such-model".into();
    t.jobs[0].schedule = "no-such-sched".into();
    t.jobs[0].engine = "no-such-engine".into();
    let d = lint_trace(&t.to_json());
    assert!(d.has_code("P204"), "unregistered names must fire P204:\n{}", d.render());
    assert!(
        d.count(Severity::Error) >= 3,
        "all three dangling names are reported:\n{}",
        d.render()
    );

    // P206: an unsigned trace is an Info, never a failure.
    let mut stripped = cxlfine::util::json::JsonObj::new();
    if let cxlfine::util::json::Json::Obj(o) = &clean.to_json() {
        for (k, v) in o.iter() {
            if k != "digest" {
                stripped.set(k, v.clone());
            }
        }
    }
    let d = lint_trace(&cxlfine::util::json::Json::Obj(stripped));
    assert!(d.has_code("P206") && !d.has_errors(), "unsigned trace is Info-only:\n{}", d.render());
}

/// Fault-trace drills: each corruption of a generated (clean) fault trace
/// fires its documented P2xx code. Target checks need the topology.
#[test]
fn fault_trace_corruptions_fire_their_codes() {
    let topo = dev_tiny();
    let clean = FaultGen::new(7, 6, 10.0).generate(&topo);
    let d = lint_fault_trace(&clean.to_json(), Some(&topo));
    assert!(
        !d.has_errors() && !d.has_warnings(),
        "generated fault trace must lint clean:\n{}",
        d.render()
    );

    let relint = |t: &FaultTrace| lint_fault_trace(&t.to_json(), Some(&topo));
    let ev = |t_s: f64, kind: FaultKind| FaultEvent { t_s, kind };

    // P207: targets that do not exist, DRAM offline, meaningless magnitudes.
    let t = FaultTrace {
        seed: 0,
        events: vec![
            ev(1.0, FaultKind::LinkDegrade { link: 999, bw_factor: 0.5 }),
            ev(2.0, FaultKind::LinkDegrade { link: 0, bw_factor: 1.5 }),
            ev(3.0, FaultKind::NodeOffline { node: 0 }),
            ev(4.0, FaultKind::CapacitySqueeze { node: 1, bytes: 0 }),
        ],
    };
    let d = relint(&t);
    assert!(d.has_code("P207"), "bad fault targets must fire P207:\n{}", d.render());
    assert!(
        d.count(Severity::Error) >= 4,
        "dangling link, bad factor, DRAM offline and zero squeeze all report:\n{}",
        d.render()
    );

    // P208: events out of time order.
    let mut t = clean.clone();
    let last = t.events.len() - 1;
    t.events[last].t_s = 0.0;
    let d = relint(&t);
    assert!(d.has_code("P208"), "unsorted fault times must fire P208:\n{}", d.render());

    // P209: double offline, and a restore with no prior offline.
    let cxl = topo.cxl_nodes()[0].0;
    let t = FaultTrace {
        seed: 0,
        events: vec![
            ev(1.0, FaultKind::NodeOffline { node: cxl }),
            ev(2.0, FaultKind::NodeOffline { node: cxl }),
        ],
    };
    let d = relint(&t);
    assert!(d.has_code("P209"), "double offline must fire P209:\n{}", d.render());
    let t = FaultTrace {
        seed: 0,
        events: vec![ev(1.0, FaultKind::NodeRestore { node: cxl })],
    };
    let d = relint(&t);
    assert!(d.has_code("P209"), "unpaired restore must fire P209:\n{}", d.render());

    // P201/P206 carry over: tampered digest errs, unsigned trace is Info.
    let mut j = clean.to_json();
    if let cxlfine::util::json::Json::Obj(o) = &mut j {
        o.set("digest", "deadbeefdeadbeef");
    }
    let d = lint_fault_trace(&j, Some(&topo));
    assert!(d.has_code("P201"), "tampered fault digest must fire P201:\n{}", d.render());

    // Without a topology the shape checks still run; target checks skip.
    let d = lint_fault_trace(&clean.to_json(), None);
    assert!(!d.has_errors(), "topology-free lint of a clean trace:\n{}", d.render());
}

/// Request-trace drills: each corruption of a generated (clean) serving
/// trace fires its documented P2xx code.
#[test]
fn request_trace_corruptions_fire_their_codes() {
    let clean = RequestGen::mixed(9, 12, "tiny-2m").generate();
    let d = lint_request_trace(&clean.to_json());
    assert!(
        !d.has_errors() && !d.has_warnings(),
        "generated request trace must lint clean:\n{}",
        d.render()
    );

    // P210: arrivals out of order (a warning, not an error).
    let mut t = clean.clone();
    let last = t.requests.len() - 1;
    t.requests[last].arrival_s = 0.0;
    let d = lint_request_trace(&t.to_json());
    assert!(d.has_code("P210"), "inverted arrivals must fire P210:\n{}", d.render());
    assert!(!d.has_errors(), "P210 is a warning:\n{}", d.render());

    // P211: non-positive token counts and SLOs, all reported.
    let mut t = clean.clone();
    t.requests[0].prompt_tokens = 0;
    t.requests[1].max_output_tokens = 0;
    t.requests[2].slo_ms = 0.0;
    let d = lint_request_trace(&t.to_json());
    assert!(d.has_code("P211"), "non-positive values must fire P211:\n{}", d.render());
    assert!(
        d.count(Severity::Error) >= 3,
        "zero prompt, zero output and zero SLO all report:\n{}",
        d.render()
    );

    // P212: digest field says one thing, contents hash to another.
    let mut j = clean.to_json();
    if let cxlfine::util::json::Json::Obj(o) = &mut j {
        o.set("digest", "deadbeefdeadbeef");
    }
    let d = lint_request_trace(&j);
    assert!(d.has_code("P212"), "corrupted digest must fire P212:\n{}", d.render());

    // Shared shapes carry over: duplicate ids (P202), unregistered model
    // (P204), malformed entries (P205), unsigned trace (P206 Info-only).
    let mut t = clean.clone();
    let id0 = t.requests[0].id;
    t.requests[1].id = id0;
    let d = lint_request_trace(&t.to_json());
    assert!(d.has_code("P202"), "duplicate ids must fire P202:\n{}", d.render());

    let mut t = clean.clone();
    t.requests[0].model = "no-such-model".into();
    let d = lint_request_trace(&t.to_json());
    assert!(d.has_code("P204"), "unregistered model must fire P204:\n{}", d.render());

    let mut j = clean.to_json();
    if let cxlfine::util::json::Json::Obj(o) = &mut j {
        let mut reqs = o.get("requests").and_then(|v| v.as_arr()).unwrap().to_vec();
        reqs[0] = cxlfine::util::json::Json::Str("not a request".into());
        o.set("requests", cxlfine::util::json::Json::Arr(reqs));
    }
    let d = lint_request_trace(&j);
    assert!(d.has_code("P205"), "malformed entries must fire P205:\n{}", d.render());

    let mut stripped = cxlfine::util::json::JsonObj::new();
    if let cxlfine::util::json::Json::Obj(o) = &clean.to_json() {
        for (k, v) in o.iter() {
            if k != "digest" {
                stripped.set(k, v.clone());
            }
        }
    }
    let d = lint_request_trace(&cxlfine::util::json::Json::Obj(stripped));
    assert!(
        d.has_code("P206") && !d.has_errors(),
        "unsigned request trace is Info-only:\n{}",
        d.render()
    );
}
