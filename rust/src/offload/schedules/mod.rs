//! Schedule builders: named fine-tuning scenarios expressed as
//! [`Schedule`] DAGs, plus the registry the CLI / sweeps resolve them
//! through (the schedule analogue of `mem::engine`).
//!
//! Registered schedules (`by_name` / `known_names`, all CLI `--schedule`
//! values):
//!
//! | Name | Scenario |
//! |---|---|
//! | `zero-offload` | the paper's Fig. 1 workflow; reproduces the legacy engine byte-for-byte |
//! | `grad-accum[:K]` | K micro-batches per optimizer step (default 4) |
//! | `lora[:R]` | frozen base model, rank-R adapters (default 16): tiny optimizer working set |
//! | `no-act-offload` | checkpoints stay in GPU HBM: the activation-traffic ablation |
//! | `prefill` | serving: forward-only prompt pass with per-block KV writeback |
//! | `decode` | serving: one autoregressive step over a context-long KV read |
//!
//! Adding a scenario = write a builder (usually by composing
//! [`zero_offload::build_fig1_passes`] with a [`zero_offload::Fig1Shape`],
//! or [`zero_offload::emit_pass`] for novel pass structures) + one arm
//! in [`by_name`].

pub mod grad_accum;
pub mod inference;
pub mod lora;
pub mod no_act_offload;
pub mod zero_offload;

use std::sync::Arc;

use super::plan::{MemoryPlan, RunConfig};
use super::schedule::Schedule;
use crate::topology::SystemTopology;

/// An object-safe schedule builder. Builders are pure functions of
/// `(topology, run config, memory plan)` — all byte counts come from the
/// plan's regions, so placement decisions show up only through stripe
/// fractions and the optimizer layout, exactly like the legacy engine.
pub trait ScheduleBuilder: Send + Sync {
    /// Registry / CLI name, e.g. `"grad-accum:4"`.
    fn name(&self) -> &str;

    fn build(&self, topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule;
}

/// Shared handle to a builder — what `RunConfig` and the sweeps thread.
pub type ScheduleRef = Arc<dyn ScheduleBuilder>;

/// The default schedule: the paper's Fig. 1 ZeRO-Offload workflow.
pub fn zero_offload() -> ScheduleRef {
    Arc::new(zero_offload::ZeroOffload)
}

/// Resolve a registry name, with an optional `:N` parameter where the
/// scenario takes one (`grad-accum:8`, `lora:64`).
pub fn by_name(name: &str) -> Option<ScheduleRef> {
    if let Some(rest) = name.strip_prefix("grad-accum") {
        let k = parse_param(rest, grad_accum::DEFAULT_MICRO_BATCHES)?;
        return Some(Arc::new(grad_accum::GradAccum::new(k)));
    }
    if let Some(rest) = name.strip_prefix("lora") {
        let r = parse_param(rest, lora::DEFAULT_RANK)?;
        return Some(Arc::new(lora::Lora::new(r)));
    }
    match name {
        "zero-offload" => Some(zero_offload()),
        "no-act-offload" => Some(Arc::new(no_act_offload::NoActOffload)),
        "prefill" => Some(Arc::new(inference::Prefill)),
        "decode" => Some(Arc::new(inference::Decode)),
        _ => None,
    }
}

/// Registry names for CLI help (parameterized entries show their syntax).
pub fn known_names() -> Vec<&'static str> {
    vec![
        "zero-offload",
        "grad-accum[:K]",
        "lora[:R]",
        "no-act-offload",
        "prefill",
        "decode",
    ]
}

/// One concrete instance of every registered scenario (parameterized
/// entries at their defaults) — what `lint --all` and registry-wide tests
/// sweep. Keep in sync with [`by_name`] / [`known_names`].
pub fn registered() -> Vec<ScheduleRef> {
    vec![
        zero_offload(),
        Arc::new(grad_accum::GradAccum::new(grad_accum::DEFAULT_MICRO_BATCHES)),
        Arc::new(lora::Lora::new(lora::DEFAULT_RANK)),
        Arc::new(no_act_offload::NoActOffload),
        Arc::new(inference::Prefill),
        Arc::new(inference::Decode),
    ]
}

fn parse_param(rest: &str, default: usize) -> Option<usize> {
    if rest.is_empty() {
        return Some(default);
    }
    rest.strip_prefix(':')?.parse().ok().filter(|&v| v >= 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_known_names() {
        assert_eq!(by_name("zero-offload").unwrap().name(), "zero-offload");
        assert_eq!(by_name("no-act-offload").unwrap().name(), "no-act-offload");
        assert_eq!(by_name("prefill").unwrap().name(), "prefill");
        assert_eq!(by_name("decode").unwrap().name(), "decode");
        assert_eq!(
            by_name("grad-accum").unwrap().name(),
            format!("grad-accum:{}", grad_accum::DEFAULT_MICRO_BATCHES)
        );
        assert_eq!(by_name("grad-accum:8").unwrap().name(), "grad-accum:8");
        assert_eq!(
            by_name("lora").unwrap().name(),
            format!("lora:{}", lora::DEFAULT_RANK)
        );
        assert_eq!(by_name("lora:64").unwrap().name(), "lora:64");
    }

    #[test]
    fn registered_covers_every_known_name() {
        let regs = registered();
        assert_eq!(regs.len(), known_names().len());
        for r in &regs {
            assert_eq!(
                by_name(r.name()).unwrap().name(),
                r.name(),
                "registered() entries must round-trip through by_name"
            );
        }
    }

    #[test]
    fn registry_rejects_garbage() {
        assert!(by_name("nope").is_none());
        assert!(by_name("grad-accum:0").is_none());
        assert!(by_name("grad-accum:x").is_none());
        assert!(by_name("lora:").is_none());
        assert!(by_name("grad-accumx").is_none());
    }
}
