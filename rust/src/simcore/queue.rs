//! The event queue: binary heap + calendar-queue (time-wheel) backends
//! behind one interface, with cohort draining (DESIGN.md §14).
//!
//! Every queue operation is defined purely over [`EventKey`] order, so the
//! two backends are observationally identical — `pop` always returns the
//! globally minimal key, bit-for-bit, whichever structure holds it. The
//! wheel exists because timer-heavy mixes (FlowSim timer storms, fleet
//! backoff/requeue bursts) are near-sorted inserts: a calendar queue turns
//! the heap's `O(log n)` sift per operation into `O(1)` amortized bucket
//! pushes plus a short cursor scan.
//!
//! # Backend selection
//!
//! [`BackendPolicy::Auto`] starts on the heap (small queues — the fleet's
//! typical few-hundred-event frontier — are fastest there) and upgrades to
//! the wheel once the queue has ever held [`WHEEL_UPGRADE_LEN`] events.
//! [`BackendPolicy::HeapOnly`] / [`BackendPolicy::WheelEager`] pin a
//! backend, used by the differential tests that prove the two produce
//! bit-identical streams.
//!
//! # Calendar-queue invariants
//!
//! Virtual bucket `vbucket(t) = min(⌊t / width⌋, VB_CAP)` is monotone in
//! `t`; physical bucket = `vbucket & mask`. The wheel maintains:
//!
//! 1. no stored entry has `vbucket < cursor` (pushes below rewind the
//!    cursor),
//! 2. within a bucket, entries are a min-heap on the full key,
//! 3. `cached_min` is either `None` or the exact global minimum key.
//!
//! `peek` scans one wheel revolution from the cursor; a physical bucket
//! whose top entry maps to the scanned virtual bucket is the global
//! minimum (any smaller key would map to an already-scanned virtual
//! bucket, and within its physical bucket it would itself be the top). A
//! full-revolution miss means the population is sparse relative to the
//! horizon — the scan falls back to a direct min over bucket tops and the
//! cursor jumps there. `VB_CAP` saturates far-future times into the last
//! virtual bucket: ordering degrades to the in-bucket heap, correctness is
//! untouched.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use super::key::EventKey;

/// `Auto` upgrades heap → wheel at this outstanding-event count.
pub const WHEEL_UPGRADE_LEN: usize = 2048;
/// `WheelEager` upgrades almost immediately (kept > 0 so an empty queue
/// has no degenerate zero-entry wheel build).
const WHEEL_EAGER_LEN: usize = 16;
/// Wheel geometry bounds: power-of-two bucket counts in this range.
const WHEEL_MIN_BUCKETS: usize = 16;
const WHEEL_MAX_BUCKETS: usize = 1 << 16;
/// Rebuild (re-size + re-width) when occupancy exceeds this per bucket.
const WHEEL_REBUILD_FACTOR: usize = 8;
/// Virtual-bucket saturation cap for far-future times (2^52 buckets).
const VB_CAP: u64 = 1 << 52;

/// One stored event; ordered by key alone so payloads need no bounds.
#[derive(Debug)]
struct Entry<P> {
    key: EventKey,
    payload: P,
}

impl<P> PartialEq for Entry<P> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<P> Eq for Entry<P> {}
impl<P> PartialOrd for Entry<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<P> Ord for Entry<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.cmp(&other.key)
    }
}

/// Which structure backs an [`EventQueue`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendPolicy {
    /// Heap while small, calendar wheel past [`WHEEL_UPGRADE_LEN`].
    Auto,
    /// Binary heap forever (differential baseline).
    HeapOnly,
    /// Calendar wheel as soon as it is non-degenerate (differential and
    /// timer-storm configurations).
    WheelEager,
}

enum Backend<P> {
    Heap(BinaryHeap<Reverse<Entry<P>>>),
    Wheel(Wheel<P>),
}

/// A priority queue over [`EventKey`]s with a payload per event.
pub struct EventQueue<P> {
    policy: BackendPolicy,
    backend: Backend<P>,
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P> EventQueue<P> {
    /// An [`BackendPolicy::Auto`] queue.
    pub fn new() -> Self {
        Self::with_policy(BackendPolicy::Auto)
    }

    pub fn with_policy(policy: BackendPolicy) -> Self {
        EventQueue {
            policy,
            backend: Backend::Heap(BinaryHeap::new()),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Wheel(w) => w.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the wheel backend is live (observability for tests/benches).
    pub fn is_wheel(&self) -> bool {
        matches!(self.backend, Backend::Wheel(_))
    }

    /// Drop every outstanding event and return to the initial backend
    /// state. A fresh queue always starts on the heap whatever its policy
    /// (upgrades happen on push), so a cleared wheel-backed queue swaps
    /// back to an empty heap: after `clear` the queue is observationally
    /// identical to [`EventQueue::with_policy`] of the same policy — the
    /// arena-reuse contract `FlowSim::reset` builds on. A retained heap
    /// keeps its capacity.
    pub fn clear(&mut self) {
        match &mut self.backend {
            Backend::Heap(h) => h.clear(),
            Backend::Wheel(_) => self.backend = Backend::Heap(BinaryHeap::new()),
        }
    }

    pub fn push(&mut self, key: EventKey, payload: P) {
        let entry = Entry { key, payload };
        let threshold = match self.policy {
            BackendPolicy::HeapOnly => usize::MAX,
            BackendPolicy::Auto => WHEEL_UPGRADE_LEN,
            BackendPolicy::WheelEager => WHEEL_EAGER_LEN,
        };
        let upgrade = matches!(&self.backend, Backend::Heap(h) if h.len() + 1 >= threshold);
        if upgrade {
            let old = std::mem::replace(&mut self.backend, Backend::Heap(BinaryHeap::new()));
            let Backend::Heap(h) = old else { unreachable!() };
            let mut all: Vec<Entry<P>> = h.into_vec().into_iter().map(|Reverse(e)| e).collect();
            all.push(entry);
            self.backend = Backend::Wheel(Wheel::build(all));
            return;
        }
        match &mut self.backend {
            Backend::Heap(h) => h.push(Reverse(entry)),
            Backend::Wheel(w) => w.push(entry),
        }
    }

    /// The minimal outstanding key. `&mut` because the wheel memoizes the
    /// scan result ([`Wheel::cached_min`]); observationally const.
    pub fn peek_key(&mut self) -> Option<EventKey> {
        match &mut self.backend {
            Backend::Heap(h) => h.peek().map(|Reverse(e)| e.key),
            Backend::Wheel(w) => w.peek(),
        }
    }

    /// Remove and return the event with the minimal key.
    pub fn pop(&mut self) -> Option<(EventKey, P)> {
        match &mut self.backend {
            Backend::Heap(h) => h.pop().map(|Reverse(e)| (e.key, e.payload)),
            Backend::Wheel(w) => w.pop(),
        }
    }

    /// Drain the full equal-timestamp cohort at the queue head into `out`
    /// (cleared first), in key order. Returns `false` on an empty queue.
    /// Cohort membership is bit-pattern time equality — exactly the
    /// equality the simulators' zero-width-step fast paths are defined
    /// over.
    pub fn pop_cohort(&mut self, out: &mut Vec<(EventKey, P)>) -> bool {
        out.clear();
        let Some((k0, p0)) = self.pop() else {
            return false;
        };
        let tb = k0.time_bits();
        out.push((k0, p0));
        while let Some(k) = self.peek_key() {
            if k.time_bits() != tb {
                break;
            }
            let next = self.pop().expect("peeked key must pop");
            out.push(next);
        }
        true
    }
}

/// The calendar-queue backend. See the module docs for the invariants.
struct Wheel<P> {
    buckets: Vec<BinaryHeap<Reverse<Entry<P>>>>,
    /// Seconds per virtual bucket.
    width: f64,
    /// `buckets.len() - 1` (power-of-two bucket count).
    mask: u64,
    /// Lower bound on every stored entry's virtual bucket.
    cursor: u64,
    len: usize,
    /// Memoized global minimum (invalidated by pop, tightened by push).
    cached_min: Option<EventKey>,
}

impl<P> Wheel<P> {
    /// Size a wheel for `entries` and insert them all. The width spreads
    /// the current population one-per-bucket across its time span, floored
    /// so that (a) a same-time population doesn't collapse to zero width
    /// and (b) `t / width` stays far from `u64` overflow for in-span
    /// times.
    fn build(entries: Vec<Entry<P>>) -> Wheel<P> {
        debug_assert!(!entries.is_empty(), "degenerate zero-entry wheel");
        let mut t_min = f64::INFINITY;
        let mut t_max: f64 = 0.0;
        for e in &entries {
            t_min = t_min.min(e.key.time());
            t_max = t_max.max(e.key.time());
        }
        let n = entries.len();
        let width = ((t_max - t_min) / n as f64).max(t_max / 1e12).max(1e-9);
        let nb = n.next_power_of_two().clamp(WHEEL_MIN_BUCKETS, WHEEL_MAX_BUCKETS);
        let mut w = Wheel {
            buckets: (0..nb).map(|_| BinaryHeap::new()).collect(),
            width,
            mask: nb as u64 - 1,
            cursor: 0,
            len: 0,
            cached_min: None,
        };
        w.cursor = w.vbucket(t_min);
        for e in entries {
            w.insert(e);
        }
        w
    }

    /// Monotone time → virtual bucket map (`as u64` saturates; the cap
    /// keeps far-future times in one final ordered-by-heap bucket).
    #[inline]
    fn vbucket(&self, t: f64) -> u64 {
        ((t / self.width) as u64).min(VB_CAP)
    }

    fn push(&mut self, e: Entry<P>) {
        if self.len + 1 >= self.buckets.len() * WHEEL_REBUILD_FACTOR
            && self.buckets.len() < WHEEL_MAX_BUCKETS
        {
            let mut all: Vec<Entry<P>> = Vec::with_capacity(self.len + 1);
            for b in &mut self.buckets {
                all.extend(b.drain().map(|Reverse(e)| e));
            }
            all.push(e);
            *self = Wheel::build(all);
            return;
        }
        self.insert(e);
    }

    fn insert(&mut self, e: Entry<P>) {
        let vb = self.vbucket(e.key.time());
        if vb < self.cursor {
            self.cursor = vb; // push below the frontier: rewind
        }
        if let Some(m) = self.cached_min {
            if e.key < m {
                self.cached_min = Some(e.key);
            }
        }
        let b = (vb & self.mask) as usize;
        self.buckets[b].push(Reverse(e));
        self.len += 1;
    }

    /// One-revolution cursor scan; falls back to a direct min over bucket
    /// tops when the population is sparse over the horizon.
    fn find_min(&self) -> EventKey {
        debug_assert!(self.len > 0);
        let nb = self.buckets.len() as u64;
        for step in 0..nb {
            let vb = self.cursor + step;
            let b = (vb & self.mask) as usize;
            if let Some(Reverse(e)) = self.buckets[b].peek() {
                if self.vbucket(e.key.time()) == vb {
                    return e.key;
                }
            }
        }
        let mut best: Option<EventKey> = None;
        for bucket in &self.buckets {
            if let Some(Reverse(e)) = bucket.peek() {
                if best.map_or(true, |m| e.key < m) {
                    best = Some(e.key);
                }
            }
        }
        best.expect("non-empty wheel has a minimum")
    }

    fn peek(&mut self) -> Option<EventKey> {
        if self.len == 0 {
            return None;
        }
        if self.cached_min.is_none() {
            let k = self.find_min();
            // The minimum's virtual bucket is a valid (tight) cursor: no
            // entry can map below the global minimum under a monotone map.
            self.cursor = self.vbucket(k.time());
            self.cached_min = Some(k);
        }
        self.cached_min
    }

    fn pop(&mut self) -> Option<(EventKey, P)> {
        let key = self.peek()?;
        let vb = self.vbucket(key.time());
        let b = (vb & self.mask) as usize;
        let Reverse(e) = self.buckets[b].pop().expect("cached min must be present");
        debug_assert_eq!(e.key, key, "bucket top must be the cached minimum");
        self.cursor = vb;
        self.len -= 1;
        self.cached_min = None;
        Some((e.key, e.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{forall, F64Range, PairOf, UsizeRange, VecOf};

    /// Quantize raw (time, kind) pairs so equal-time cohorts actually
    /// occur; seq = input index keeps every key unique.
    fn schedule(raw: &[(f64, usize)]) -> Vec<(EventKey, usize)> {
        raw.iter()
            .enumerate()
            .map(|(i, &(t, kind))| {
                let t = (t * 64.0).floor() / 16.0;
                (EventKey::new(t, kind as u8, i as u64), i)
            })
            .collect()
    }

    fn gen_sched(max_len: usize) -> VecOf<PairOf<F64Range, UsizeRange>> {
        VecOf {
            inner: PairOf(F64Range { lo: 0.0, hi: 1.0 }, UsizeRange { lo: 0, hi: 3 }),
            min_len: 1,
            max_len,
        }
    }

    #[test]
    fn prop_random_schedules_dispatch_in_key_order() {
        forall("simcore-key-order", 11, 16, &gen_sched(200), |raw| {
            let sched = schedule(raw);
            let mut q = EventQueue::new();
            for &(k, p) in &sched {
                q.push(k, p);
            }
            let mut popped = Vec::new();
            while let Some(e) = q.pop() {
                popped.push(e);
            }
            if popped.len() != sched.len() {
                return Err(format!("lost events: {} of {}", popped.len(), sched.len()));
            }
            for w in popped.windows(2) {
                if w[0].0 >= w[1].0 {
                    return Err(format!("out of order: {:?} then {:?}", w[0].0, w[1].0));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_wheel_and_heap_backends_are_bit_identical() {
        forall("simcore-wheel-vs-heap", 23, 16, &gen_sched(300), |raw| {
            let sched = schedule(raw);
            let mut heap = EventQueue::with_policy(BackendPolicy::HeapOnly);
            let mut wheel = EventQueue::with_policy(BackendPolicy::WheelEager);
            let mut hs = Vec::new();
            let mut ws = Vec::new();
            // Interleave pops with the pushes so later pushes land below
            // the wheel cursor (the rewind path) mid-stream.
            for (i, &(k, p)) in sched.iter().enumerate() {
                heap.push(k, p);
                wheel.push(k, p);
                if i % 3 == 2 {
                    hs.push(heap.pop());
                    ws.push(wheel.pop());
                }
            }
            while let Some(e) = heap.pop() {
                hs.push(Some(e));
            }
            while let Some(e) = wheel.pop() {
                ws.push(Some(e));
            }
            if hs != ws {
                return Err(format!("streams diverge:\n  heap  {hs:?}\n  wheel {ws:?}"));
            }
            if !heap.is_empty() || !wheel.is_empty() {
                return Err("residual events after drain".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cohorts_are_atomic_maximal_and_sorted() {
        forall("simcore-cohorts", 37, 16, &gen_sched(200), |raw| {
            let sched = schedule(raw);
            let mut q = EventQueue::new();
            for &(k, p) in &sched {
                q.push(k, p);
            }
            let mut cohort = Vec::new();
            let mut seen = 0usize;
            let mut last_tb: Option<u64> = None;
            while q.pop_cohort(&mut cohort) {
                let tb = cohort[0].0.time_bits();
                if cohort.iter().any(|(k, _)| k.time_bits() != tb) {
                    return Err("cohort mixes timestamps".into());
                }
                if let Some(prev) = last_tb {
                    if f64::from_bits(tb) <= f64::from_bits(prev) {
                        return Err("cohorts not strictly time-ordered (non-maximal)".into());
                    }
                }
                for w in cohort.windows(2) {
                    if w[0].0 >= w[1].0 {
                        return Err("cohort not key-sorted internally".into());
                    }
                }
                last_tb = Some(tb);
                seen += cohort.len();
            }
            if seen != sched.len() {
                return Err("cohorts lost events".into());
            }
            Ok(())
        });
    }

    #[test]
    fn auto_upgrades_to_wheel_mid_stream_and_stays_sorted() {
        let mut q = EventQueue::new();
        let mut golden = EventQueue::with_policy(BackendPolicy::HeapOnly);
        assert!(!q.is_wheel());
        let mut x = 1u64; // LCG: deterministic pseudo-random times
        for i in 0..4000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let t = (x >> 11) as f64 / (1u64 << 53) as f64 * 1e3;
            let k = EventKey::new(t, (i % 4) as u8, i);
            q.push(k, i);
            golden.push(k, i);
        }
        assert!(q.is_wheel(), "Auto must upgrade past WHEEL_UPGRADE_LEN");
        assert!(!golden.is_wheel());
        assert_eq!(q.len(), 4000);
        loop {
            let (a, b) = (q.pop(), golden.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn wheel_handles_pushes_below_the_cursor() {
        let mut q = EventQueue::with_policy(BackendPolicy::WheelEager);
        for i in 0..64u64 {
            q.push(EventKey::new(1000.0 + i as f64, 0, i), i);
        }
        assert!(q.is_wheel());
        assert_eq!(q.pop().unwrap().0.time(), 1000.0);
        // A fresh event earlier than everything outstanding must surface
        // first (cursor rewind), then the stream resumes where it was.
        q.push(EventKey::new(0.5, 0, 999), 999);
        assert_eq!(q.peek_key().unwrap().time(), 0.5);
        assert_eq!(q.pop().unwrap().1, 999);
        assert_eq!(q.pop().unwrap().0.time(), 1001.0);
    }

    #[test]
    fn far_future_events_saturate_but_stay_ordered() {
        let mut q = EventQueue::with_policy(BackendPolicy::WheelEager);
        for i in 0..32u64 {
            q.push(EventKey::new(i as f64 * 1e-6, 0, i), i);
        }
        q.push(EventKey::new(1e30, 0, 100), 100);
        q.push(EventKey::new(2e30, 0, 101), 101);
        let mut last: Option<EventKey> = None;
        let mut n = 0;
        while let Some((k, _)) = q.pop() {
            if let Some(p) = last {
                assert!(k > p, "saturated tail must still dispatch in order");
            }
            last = Some(k);
            n += 1;
        }
        assert_eq!(n, 34);
    }

    #[test]
    fn clear_restores_the_initial_backend_state() {
        // Heap-backed: clear drops the events, stays a heap.
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..8u64 {
            q.push(EventKey::new(i as f64, 0, i), i);
        }
        q.clear();
        assert!(q.is_empty() && !q.is_wheel());
        // Wheel-backed: clear swaps back to the empty heap a fresh queue
        // of the same policy would start on, and the reused queue's event
        // stream is bit-identical to a fresh one's.
        let mut reused = EventQueue::with_policy(BackendPolicy::WheelEager);
        for i in 0..64u64 {
            reused.push(EventKey::new(i as f64 * 0.5, 0, i), i);
        }
        assert!(reused.is_wheel());
        reused.clear();
        assert!(reused.is_empty() && !reused.is_wheel());
        let mut fresh = EventQueue::with_policy(BackendPolicy::WheelEager);
        for i in 0..64u64 {
            let k = EventKey::new((i % 7) as f64, (i % 3) as u8, i);
            reused.push(k, i);
            fresh.push(k, i);
        }
        loop {
            let (a, b) = (reused.pop(), fresh.pop());
            assert_eq!(a, b, "reused queue drifted from fresh");
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn pop_cohort_on_empty_queue_is_false_and_clears_out() {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut out = vec![(EventKey::new(0.0, 0, 0), 1u32)];
        assert!(!q.pop_cohort(&mut out));
        assert!(out.is_empty(), "out must be cleared even on empty queues");
        q.push(EventKey::new(1.0, 0, 0), 7);
        q.push(EventKey::new(1.0, 1, 1), 8);
        q.push(EventKey::new(2.0, 0, 2), 9);
        assert!(q.pop_cohort(&mut out));
        assert_eq!(out.len(), 2, "both t=1.0 events in one cohort");
        assert_eq!((out[0].1, out[1].1), (7, 8));
        assert_eq!(q.len(), 1);
    }
}
