//! Ablation: activation checkpoints stay in GPU HBM — no D2H offload
//! after each forward block and no H2D reload before each backward block.
//!
//! This isolates how much of a policy's win/loss comes from *activation*
//! traffic placement versus parameter streams and the optimizer step: the
//! paper's CXL-aware policy routes checkpoints to per-GPU AIC affinity
//! (or stripes them), and comparing `zero-offload` vs `no-act-offload`
//! under the same engine prices exactly that traffic. On real hardware
//! this trades HBM capacity for PCIe bandwidth; the simulator assumes the
//! checkpoints fit.

use super::super::plan::{MemoryPlan, RunConfig};
use super::super::schedule::Schedule;
use super::zero_offload::{build_fig1_passes, full_model_cpu_step, Fig1Shape};
use super::ScheduleBuilder;
use crate::topology::SystemTopology;

pub struct NoActOffload;

impl ScheduleBuilder for NoActOffload {
    fn name(&self) -> &str {
        "no-act-offload"
    }

    fn build(&self, _topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule {
        let (mut s, all_grads, step) = build_fig1_passes(
            cfg,
            plan,
            &Fig1Shape {
                offload_activations: false,
                ..Fig1Shape::default()
            },
        );
        s.push(full_model_cpu_step(cfg, plan, all_grads, step));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::footprint::Workload;
    use crate::model::presets::tiny_2m;
    use crate::offload::executor::execute;
    use crate::offload::schedules::zero_offload::ZeroOffload;
    use crate::topology::presets::dev_tiny;

    #[test]
    fn no_checkpoint_traffic_and_never_slower() {
        let topo = dev_tiny();
        // DRAM-only placement → one stripe per logical transfer, so span
        // counts are exact; removing the checkpoint round-trips can only
        // relieve the shared DRAM controller.
        let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let zo = execute(&topo, &ZeroOffload.build(&topo, &cfg, &plan));
        let na = execute(&topo, &NoActOffload.build(&topo, &cfg, &plan));
        assert!(
            !na.trace
                .spans()
                .iter()
                .any(|sp| sp.name.starts_with("ckpt-")),
            "ablation must emit no checkpoint spans"
        );
        assert!(zo
            .trace
            .spans()
            .iter()
            .any(|sp| sp.name.starts_with("ckpt-offload")));
        // removing traffic can only help (same kernels, fewer flows)
        assert!(na.report.iter_s <= zo.report.iter_s * (1.0 + 1e-9));
        // per GPU: L loads + L fwd + L reloads + L bwd + L grads = 5L + step
        let l = cfg.model.layers;
        assert_eq!(na.trace.spans().len(), 2 * 5 * l + 1);
    }
}
