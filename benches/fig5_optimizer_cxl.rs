//! Fig. 5: CPU Adam optimizer time vs element count, local DRAM vs
//! CXL-attached memory.
//!
//! Paper shape: negligible difference below the cache knee; CXL rises
//! sharply past ~20 M elements, reaching ≈4× the DRAM baseline.
//!
//! Two data sources:
//! * the calibrated timing model (both placements, Config A constants),
//! * the REAL Rust Adam measured on this host's DRAM (functional check of
//!   the hot path + §Perf baseline; this machine has no CXL AIC, so the
//!   CXL line is model-only — that substitution is documented in
//!   DESIGN.md §2).

use cxlfine::optim::{adam_step, AdamHp, AdamState};
use cxlfine::sim::memmodel::{OptLayout, OptimizerMemModel};
use cxlfine::topology::presets::config_a;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::threadpool::default_threads;

fn measure_host_adam(n: usize) -> f64 {
    let mut p = vec![1.0f32; n];
    let g: Vec<f32> = (0..n).map(|i| (i as f32).sin() * 0.1).collect();
    let mut st = AdamState::new(n);
    let hp = AdamHp::default();
    let threads = default_threads();
    // warm
    adam_step(&mut p, &g, &mut st, &hp, threads);
    let iters = if n <= 5_000_000 { 5 } else { 2 };
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        adam_step(&mut p, &g, &mut st, &hp, threads);
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let mut report = BenchReport::new("fig5_optimizer_cxl");
    let topo = config_a();
    let mm = OptimizerMemModel::new(&topo);
    let cxl = topo.cxl_nodes()[0];
    let dram_layout = OptLayout::dram_only();
    let cxl_layout = OptLayout::single_node(cxl);

    let mut t = Table::new(&[
        "elements",
        "sim DRAM (ms)",
        "sim CXL (ms)",
        "ratio",
        "host DRAM measured (ms)",
    ]);
    let counts: Vec<u64> = vec![
        1_000_000, 2_000_000, 5_000_000, 10_000_000, 20_000_000, 50_000_000, 100_000_000,
        200_000_000, 500_000_000,
    ];
    let (mut xs, mut sim_d, mut sim_c, mut host) = (vec![], vec![], vec![], vec![]);
    for &n in &counts {
        let td = mm.step_time(n, &dram_layout);
        let tc = mm.step_time(n, &cxl_layout);
        let measured = if n <= 100_000_000 {
            measure_host_adam(n as usize)
        } else {
            f64::NAN
        };
        t.row(trow![
            n,
            format!("{:.2}", td * 1e3),
            format!("{:.2}", tc * 1e3),
            format!("{:.2}x", tc / td),
            if measured.is_nan() {
                "-".into()
            } else {
                format!("{:.2}", measured * 1e3)
            }
        ]);
        xs.push(n as f64);
        sim_d.push(td);
        sim_c.push(tc);
        host.push(measured);
    }
    // ---- paper-shape assertions ------------------------------------
    // small-N parity
    assert!(sim_c[0] / sim_d[0] < 1.01, "small-N parity broken");
    // large-N ~4x
    let big_ratio = sim_c[7] / sim_d[7];
    assert!(
        (3.2..4.8).contains(&big_ratio),
        "200M-element CXL ratio {big_ratio}"
    );
    // knee: divergence (>1.5x) starts in the 5–40M band
    let knee = counts
        .iter()
        .zip(sim_c.iter().zip(&sim_d))
        .find(|(_, (c, d))| *c / **d > 1.5)
        .map(|(n, _)| *n)
        .expect("no knee found");
    assert!(
        (5_000_000..=40_000_000).contains(&knee),
        "knee at {knee} elements"
    );
    println!("knee (CXL ≥ 1.5× DRAM) at {knee} elements; 200M-element ratio {big_ratio:.2}x");

    report.section(
        "step_time_vs_elements",
        t,
        points_json(
            &xs,
            &[
                ("sim_dram_s", &sim_d),
                ("sim_cxl_s", &sim_c),
                ("host_dram_s", &host),
            ],
        ),
    );
    report.finish();
}
