//! `cxlfine` command-line interface.
//!
//! Subcommands:
//! * `topo`      — print a hardware preset,
//! * `plan`      — Table-I footprint + memory placement for a run,
//! * `simulate`  — one iteration's phase breakdown under a policy,
//! * `sweep`     — (C, B) policy grid normalized to baseline (Fig. 9/10),
//! * `optimizer` — CPU Adam step time vs element count (Fig. 5; sim + real),
//! * `bandwidth` — host→GPU transfer bandwidth matrix (Fig. 6),
//! * `train`     — run the functional fine-tuning loop on the artifacts,
//! * `fleet`     — multi-tenant job scheduling on one shared DRAM+CXL host,
//! * `serve`     — request-level inference over a CXL-tiered paged KV cache,
//! * `lint`      — static verifier for schedules, memory plans, and traces.

pub mod commands;

use crate::util::cli::{CliError, CliSpec};

/// Top-level dispatch. Returns process exit code.
pub fn run(args: Vec<String>) -> i32 {
    crate::util::logging::init_from_env();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", usage());
        return 2;
    };
    let result = match cmd.as_str() {
        "topo" => commands::topo(rest),
        "plan" => commands::plan(rest),
        "simulate" => commands::simulate(rest),
        "sweep" => commands::sweep(rest),
        "optimizer" => commands::optimizer(rest),
        "bandwidth" => commands::bandwidth(rest),
        "train" => commands::train(rest),
        "trace" => commands::trace(rest),
        "fleet" => commands::fleet(rest),
        "serve" => commands::serve(rest),
        "lint" => commands::lint(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            return 0;
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{}", usage());
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(CliDone::Help(text)) => {
            println!("{text}");
            0
        }
        Err(CliDone::Bad(msg)) => {
            eprintln!("{msg}");
            2
        }
        Err(CliDone::Runtime(e)) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn usage() -> String {
    "cxlfine — CXL-aware memory allocation for long-context LLM fine-tuning\n\
     (reproduction of Liaw & Chen, CS.DC 2025)\n\n\
     USAGE: cxlfine <command> [options]   (--help on any command)\n\n\
     COMMANDS:\n  \
       topo       print a hardware preset (config-a | config-b | dev-tiny)\n  \
       plan       Table-I memory footprint + placement for a run\n  \
       simulate   one iteration's phase breakdown (--schedule picks the scenario)\n  \
       sweep      (context, batch) engine x schedule grid vs baseline (Fig. 9/10)\n  \
       optimizer  CPU Adam time vs element count, DRAM vs CXL (Fig. 5)\n  \
       bandwidth  host->GPU DMA bandwidth matrix (Fig. 6)\n  \
       train      run the functional fine-tuning loop on AOT artifacts\n  \
       trace      export a chrome://tracing JSON of one simulated iteration\n  \
       fleet      multi-tenant job scheduling + online capacity management (--trace/--policy)\n  \
       serve      request-level inference over a CXL-tiered paged KV cache (--kv-policy)\n  \
       lint       static verifier: schedules x plans x traces (--all --deny-warnings)"
        .to_string()
}

/// Command error plumbing.
pub enum CliDone {
    Help(String),
    Bad(String),
    Runtime(anyhow::Error),
}

impl From<CliError> for CliDone {
    fn from(e: CliError) -> Self {
        match e {
            CliError::Help(h) => CliDone::Help(h),
            CliError::Bad(m) => CliDone::Bad(m),
        }
    }
}

impl From<anyhow::Error> for CliDone {
    fn from(e: anyhow::Error) -> Self {
        CliDone::Runtime(e)
    }
}

pub(crate) fn parse(spec: CliSpec, args: &[String]) -> Result<crate::util::cli::CliArgs, CliDone> {
    spec.parse(args).map_err(CliDone::from)
}
