//! Golden event-trace tests: the determinism contract of the slab/heap DES
//! refactor (DESIGN.md §7).
//!
//! Two independent locks:
//!
//! 1. **Differential (refactor-proof)** — [`cxlfine::sim::flow::FlowSim`]
//!    (slab/heap engine) and [`cxlfine::sim::reference::RefFlowSim`] (the
//!    frozen pre-refactor HashMap engine) are driven through identical call
//!    sequences — Fig. 6-shaped contention scenarios, a Fig. 1-style
//!    prefetch workflow, and seeded randomized scenarios — and must emit
//!    **byte-identical** event streams: same ids, same tags, same order,
//!    and `now()` timestamps equal under `to_bits`.
//!
//! 2. **Golden digests (version-proof)** — full Fig. 6/7/9/10 cell traces
//!    are FNV-1a digested (names, lanes, bit-pattern timestamps) and pinned
//!    in `rust/tests/golden/*.digest`. The first run on a toolchain host
//!    blesses the files; every later run — debug or release, the digest is
//!    pure IEEE-754 arithmetic and container-order-free — must reproduce
//!    them exactly. Delete a file to re-bless after an *intentional*
//!    behavior change.

mod common;

use cxlfine::mem::Policy;
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::offload::{simulate_iteration_traced, MemoryPlan, RunConfig};
use cxlfine::sim::flow::{CapacityModel, Event, FlowId, FlowSim, FlowStats, ResourceId, TimerId};
use cxlfine::sim::reference::RefFlowSim;
use cxlfine::topology::presets::{config_a, config_b, with_dram_capacity};
use cxlfine::util::digest::Fnv64;
use cxlfine::util::prng::Xoshiro256pp;
use cxlfine::util::units::GIB;

const GB: f64 = 1e9;

// ---------------------------------------------------------------------
// A minimal common surface over the two engines so every scenario is
// written once and replayed verbatim against both.
// ---------------------------------------------------------------------

trait Des {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId;
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) -> FlowId;
    fn add_timer(&mut self, delay: f64, tag: u64) -> TimerId;
    fn next_event(&mut self) -> Option<Event>;
    fn now(&self) -> f64;
    fn stats(&self, id: FlowId) -> Option<FlowStats>;
}

impl Des for FlowSim {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        FlowSim::add_resource(self, name, model)
    }
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) -> FlowId {
        FlowSim::start_flow(self, path, bytes, setup, tag)
    }
    fn add_timer(&mut self, delay: f64, tag: u64) -> TimerId {
        FlowSim::add_timer(self, delay, tag)
    }
    fn next_event(&mut self) -> Option<Event> {
        FlowSim::next_event(self)
    }
    fn now(&self) -> f64 {
        FlowSim::now(self)
    }
    fn stats(&self, id: FlowId) -> Option<FlowStats> {
        FlowSim::stats(self, id)
    }
}

impl Des for RefFlowSim {
    fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        RefFlowSim::add_resource(self, name, model)
    }
    fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) -> FlowId {
        RefFlowSim::start_flow(self, path, bytes, setup, tag)
    }
    fn add_timer(&mut self, delay: f64, tag: u64) -> TimerId {
        RefFlowSim::add_timer(self, delay, tag)
    }
    fn next_event(&mut self) -> Option<Event> {
        RefFlowSim::next_event(self)
    }
    fn now(&self) -> f64 {
        RefFlowSim::now(self)
    }
    fn stats(&self, id: FlowId) -> Option<FlowStats> {
        RefFlowSim::stats(self, id)
    }
}

/// One recorded step of an event stream: the event plus the bit pattern of
/// the simulator clock at delivery. `to_bits` makes equality byte-exact.
type Recorded = (Event, u64);

/// Bit-exact digest of a recorded event stream (also locks ids and tags).
fn stream_digest(events: &[Recorded]) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(events.len() as u64);
    for (e, now_bits) in events {
        match e {
            Event::FlowDone { id, tag } => {
                h.write_u64(0).write_u64(id.0).write_u64(*tag);
            }
            Event::TimerFired { id, tag } => {
                h.write_u64(1).write_u64(id.0).write_u64(*tag);
            }
        }
        h.write_u64(*now_bits);
    }
    h.finish()
}

/// Assert two engines produced literally the same stream.
fn assert_streams_identical(new: &[Recorded], reference: &[Recorded], what: &str) {
    assert_eq!(
        new.len(),
        reference.len(),
        "{what}: event counts diverge (new {} vs reference {})",
        new.len(),
        reference.len()
    );
    for (i, (n, r)) in new.iter().zip(reference.iter()).enumerate() {
        assert_eq!(
            n, r,
            "{what}: event #{i} diverges — new {:?} @ {} vs reference {:?} @ {}",
            n.0,
            f64::from_bits(n.1),
            r.0,
            f64::from_bits(r.1)
        );
    }
}

/// Assert the final per-flow stats match bit-for-bit for ids `0..n_ids`
/// (ids are monotonic and shared with timers, so probing the full range
/// covers every flow; timer ids simply return `None` in both).
fn assert_stats_identical<A: Des, B: Des>(a: &A, b: &B, n_ids: u64, what: &str) {
    for id in 0..n_ids {
        let (sa, sb) = (a.stats(FlowId(id)), b.stats(FlowId(id)));
        match (sa, sb) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(
                    (
                        x.issued.to_bits(),
                        x.started.to_bits(),
                        x.finished.to_bits(),
                        x.bytes.to_bits()
                    ),
                    (
                        y.issued.to_bits(),
                        y.started.to_bits(),
                        y.finished.to_bits(),
                        y.bytes.to_bits()
                    ),
                    "{what}: stats for flow {id} diverge"
                );
            }
            other => panic!("{what}: stats presence diverges for id {id}: {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// Golden-digest persistence (self-blessing).
// ---------------------------------------------------------------------

/// Compare-or-bless via the shared helper (`rust/tests/common/mod.rs`).
fn assert_golden_digest(name: &str, digest: u64) {
    common::assert_golden_digest("golden_trace", name, digest);
}

// ---------------------------------------------------------------------
// Scenario scripts: generated once, replayed verbatim on both engines.
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
enum Op {
    Flow { path: Vec<usize>, bytes: f64, setup: f64, tag: u64 },
    Timer { delay: f64, tag: u64 },
}

#[derive(Clone, Debug)]
struct Script {
    /// Resource table shared by every flow path (indices into it).
    resources: Vec<(String, CapacityModel)>,
    /// Ops issued before the first event is consumed.
    initial: Vec<Op>,
    /// Follow-up ops: `followups[k]` is issued right after the k-th event.
    followups: Vec<Vec<Op>>,
}

impl Script {
    /// Replay on an engine, interleaving follow-up ops with the event loop
    /// exactly as the workflow engine does.
    fn replay<S: Des>(&self, sim: &mut S) -> Vec<Recorded> {
        let rids: Vec<ResourceId> = self
            .resources
            .iter()
            .map(|(name, model)| sim.add_resource(name, model.clone()))
            .collect();
        let issue = |sim: &mut S, op: &Op| match op {
            Op::Flow { path, bytes, setup, tag } => {
                let p: Vec<ResourceId> = path.iter().map(|&i| rids[i]).collect();
                sim.start_flow(&p, *bytes, *setup, *tag);
            }
            Op::Timer { delay, tag } => {
                sim.add_timer(*delay, *tag);
            }
        };
        for op in &self.initial {
            issue(sim, op);
        }
        let mut recorded = Vec::new();
        while let Some(e) = sim.next_event() {
            recorded.push((e, sim.now().to_bits()));
            let k = recorded.len() - 1;
            if let Some(ops) = self.followups.get(k) {
                for op in ops {
                    issue(sim, op);
                }
            }
        }
        recorded
    }

    /// Total ids consumed (flows + timers), for stats probing.
    fn n_ids(&self) -> u64 {
        (self.initial.len() + self.followups.iter().map(Vec::len).sum::<usize>()) as u64
    }

    /// Run on both engines; assert byte-identical streams and stats.
    /// Returns the (shared) stream digest.
    fn assert_engines_agree(&self, what: &str) -> u64 {
        let mut new_sim = FlowSim::new();
        let mut ref_sim = RefFlowSim::new();
        let new_stream = self.replay(&mut new_sim);
        let ref_stream = self.replay(&mut ref_sim);
        assert_streams_identical(&new_stream, &ref_stream, what);
        assert_stats_identical(&new_sim, &ref_sim, self.n_ids(), what);
        stream_digest(&new_stream)
    }
}

/// The Fig. 6b scenario: two GPUs pulling page-locked copies from one AIC
/// (collapse), then a third flow from DRAM, with DMA setup latencies and a
/// poll timer — the exact resource shapes `Fabric::new` instantiates.
fn fig6_script() -> Script {
    let resources = vec![
        ("dram-ctrl".to_string(), CapacityModel::Fixed(204.0 * GB)),
        (
            "aic-tx".to_string(),
            CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB },
        ),
        ("gpu0-rx".to_string(), CapacityModel::Fixed(54.0 * GB)),
        ("gpu1-rx".to_string(), CapacityModel::Fixed(54.0 * GB)),
    ];
    let setup = 10e-6 + 210e-9; // DMA_SETUP_S + CXL load-to-use latency
    let initial = vec![
        Op::Flow { path: vec![1, 2], bytes: 4.0 * GIB as f64, setup, tag: 0 },
        Op::Flow { path: vec![1, 3], bytes: 4.0 * GIB as f64, setup, tag: 1 },
        Op::Flow { path: vec![0, 2], bytes: 1.0 * GIB as f64, setup: 10e-6 + 105e-9, tag: 2 },
        Op::Timer { delay: 0.05, tag: 3 },
    ];
    // after the first completion, issue a solo AIC flow (uncollapsed regime)
    let followups = vec![
        vec![Op::Flow { path: vec![1, 3], bytes: 2.0 * GIB as f64, setup, tag: 4 }],
    ];
    Script { resources, initial, followups }
}

/// A Fig. 1-style miniature of the iteration workflow: block-by-block
/// parameter prefetch with compute timers and checkpoint offloads chained
/// off completions — the event pattern `offload::iteration` generates,
/// shrunk to flow level so the frozen engine can run it too.
fn workflow_script() -> Script {
    let resources = vec![
        ("dram-ctrl".to_string(), CapacityModel::Fixed(204.0 * GB)),
        (
            "aic-tx".to_string(),
            CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB },
        ),
        (
            "aic-rx".to_string(),
            CapacityModel::Contended { single: 54.0 * GB, contended: 26.0 * GB },
        ),
        ("gpu0-rx".to_string(), CapacityModel::Fixed(54.0 * GB)),
        ("gpu0-tx".to_string(), CapacityModel::Fixed(54.0 * GB)),
        ("gpu1-rx".to_string(), CapacityModel::Fixed(54.0 * GB)),
        ("gpu1-tx".to_string(), CapacityModel::Fixed(54.0 * GB)),
    ];
    let setup = 10e-6 + 210e-9;
    let param = 0.4 * GB;
    let ckpt = 0.25 * GB;
    // two GPUs, four "blocks" each: prefetch depth 2, per-block compute
    // timer, checkpoint offload after compute
    let mut initial = Vec::new();
    for g in 0..2usize {
        let rx = 3 + 2 * g;
        for block in 0..2u64 {
            initial.push(Op::Flow {
                path: vec![1, rx],
                bytes: param,
                setup,
                tag: 100 * (g as u64 + 1) + block,
            });
        }
    }
    // follow-ups keyed on event index: a rolling pattern of compute timers,
    // further prefetches, and d2h checkpoint offloads (even/odd split the
    // two directions so tx and rx both see contention windows)
    let mut followups = Vec::new();
    for k in 0..24usize {
        let mut ops = Vec::new();
        if k % 2 == 0 {
            ops.push(Op::Timer { delay: 0.8e-3 + 0.05e-3 * k as f64, tag: 1000 + k as u64 });
        }
        if k % 3 == 0 {
            let g = k % 2;
            ops.push(Op::Flow {
                path: vec![1, 3 + 2 * g],
                bytes: param,
                setup,
                tag: 2000 + k as u64,
            });
        }
        if k % 4 == 1 {
            let g = k % 2;
            ops.push(Op::Flow {
                path: vec![4 + 2 * g, 2],
                bytes: ckpt,
                setup,
                tag: 3000 + k as u64,
            });
        }
        followups.push(ops);
    }
    Script { resources, initial, followups }
}

/// Seeded random scenario: mixed fixed/contended resources, random paths
/// (1–3 hops), zero-byte flows, duplicate timer deadlines, interactive
/// follow-ups — fuzzes the corner cases the structured scripts miss.
fn random_script(seed: u64) -> Script {
    let mut rng = Xoshiro256pp::seeded(seed);
    let n_res = rng.range_usize(3, 8);
    let mut resources = Vec::new();
    for i in 0..n_res {
        let model = if i > 0 && rng.below(3) == 0 {
            let single = rng.range_f64(20.0, 60.0) * GB;
            CapacityModel::Contended { single, contended: single * rng.range_f64(0.3, 0.7) }
        } else {
            CapacityModel::Fixed(rng.range_f64(10.0, 210.0) * GB)
        };
        resources.push((format!("r{i}"), model));
    }
    let mut tag = 0u64;
    let mk_flow = |rng: &mut Xoshiro256pp, tag: &mut u64| {
        let hops = rng.range_usize(1, n_res.min(3));
        let mut path = Vec::with_capacity(hops);
        for _ in 0..hops {
            let r = rng.range_usize(0, n_res - 1);
            if !path.contains(&r) {
                path.push(r);
            }
        }
        if path.is_empty() {
            path.push(rng.range_usize(0, n_res - 1));
        }
        let bytes = match rng.below(8) {
            0 => 0.0, // zero-byte flow (completes at activation)
            _ => rng.range_f64(1e6, 3e9),
        };
        let setup = match rng.below(3) {
            0 => 0.0,
            1 => 10e-6,              // identical setups → same-instant bursts
            _ => rng.range_f64(1e-6, 5e-3),
        };
        *tag += 1;
        Op::Flow { path, bytes, setup, tag: *tag }
    };
    let n_initial = rng.range_usize(5, 25);
    let mut initial = Vec::new();
    for _ in 0..n_initial {
        if rng.below(5) == 0 {
            tag += 1;
            let delay = if rng.below(2) == 0 { 1e-3 } else { rng.range_f64(0.0, 0.05) };
            initial.push(Op::Timer { delay, tag });
        } else {
            initial.push(mk_flow(&mut rng, &mut tag));
        }
    }
    let mut followups = Vec::new();
    for _ in 0..rng.range_usize(4, 16) {
        let mut ops = Vec::new();
        if rng.below(2) == 0 {
            ops.push(mk_flow(&mut rng, &mut tag));
        }
        if rng.below(4) == 0 {
            tag += 1;
            ops.push(Op::Timer { delay: rng.range_f64(0.0, 0.01), tag });
        }
        followups.push(ops);
    }
    Script { resources, initial, followups }
}

// ---------------------------------------------------------------------
// Differential tests: new engine vs frozen pre-refactor engine.
// ---------------------------------------------------------------------

#[test]
fn fig6_contention_scenario_bit_identical_to_reference() {
    let digest = fig6_script().assert_engines_agree("fig6");
    assert_golden_digest("fig6_contention_events", digest);
}

#[test]
fn workflow_scenario_bit_identical_to_reference() {
    let digest = workflow_script().assert_engines_agree("workflow");
    assert_golden_digest("workflow_events", digest);
}

#[test]
fn randomized_scenarios_bit_identical_to_reference() {
    for seed in 0..32u64 {
        random_script(seed).assert_engines_agree(&format!("random seed {seed}"));
    }
}

#[test]
fn replay_is_deterministic_across_runs() {
    // Same engine, two fresh instances: the stream digest cannot depend on
    // any container iteration order or allocation address.
    for seed in [3u64, 17, 29] {
        let script = random_script(seed);
        let mut a = FlowSim::new();
        let mut b = FlowSim::new();
        let da = stream_digest(&script.replay(&mut a));
        let db = stream_digest(&script.replay(&mut b));
        assert_eq!(da, db, "seed {seed} replay must be bit-stable");
    }
}

// ---------------------------------------------------------------------
// Golden full-figure traces: lock complete Fig. 7/9/10 cells.
// ---------------------------------------------------------------------

fn cell_trace_digest(
    topo: &cxlfine::topology::SystemTopology,
    model: cxlfine::model::ModelConfig,
    w: Workload,
    policy: Policy,
) -> u64 {
    let cfg = RunConfig::new(model, w, policy);
    let plan = MemoryPlan::build(topo, &cfg).expect("cell must fit");
    let (_, trace) = simulate_iteration_traced(topo, &cfg, &plan);
    assert!(!trace.is_empty());
    trace.digest()
}

#[test]
fn golden_fig9_cell_cxl_aware() {
    // Fig. 9a cell: Qwen-7B, 1 GPU, B=8, C=4096, CXL-aware placement.
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let d = cell_trace_digest(
        &topo,
        qwen25_7b(),
        Workload::new(1, 8, 4096),
        Policy::CxlAware { striping: false },
    );
    // a second run in-process must agree before we compare to disk
    let d2 = cell_trace_digest(
        &topo,
        qwen25_7b(),
        Workload::new(1, 8, 4096),
        Policy::CxlAware { striping: false },
    );
    assert_eq!(d, d2, "fig9 cell trace must be run-to-run deterministic");
    assert_golden_digest("fig9_cell_qwen7b_c4096_b8_cxl_aware", d);
}

#[test]
fn golden_fig7_cell_naive_breakdown() {
    // Fig. 7a cell: Mistral-NeMo-12B, 1 GPU, B=16, C=4096, naive interleave.
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let d = cell_trace_digest(
        &topo,
        mistral_nemo_12b(),
        Workload::new(1, 16, 4096),
        Policy::NaiveInterleave,
    );
    assert_golden_digest("fig7_cell_nemo12b_c4096_b16_naive", d);
}

#[test]
fn golden_fig10_cell_dual_aic_striping() {
    // Fig. 10 cell: Mistral-NeMo-12B, 2 GPUs, B=16, C=4096, striping over
    // both AICs (Config B).
    let topo = with_dram_capacity(config_b(), 128 * GIB);
    let d = cell_trace_digest(
        &topo,
        mistral_nemo_12b(),
        Workload::new(2, 16, 4096),
        Policy::CxlAware { striping: true },
    );
    assert_golden_digest("fig10_cell_nemo12b_c4096_b16_striped", d);
}

#[test]
fn golden_digests_distinguish_policies() {
    // Sanity on the lock itself: different placements produce different
    // event sequences, so the digests cannot be trivially colliding.
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let naive = cell_trace_digest(
        &topo,
        qwen25_7b(),
        Workload::new(1, 8, 4096),
        Policy::NaiveInterleave,
    );
    let ours = cell_trace_digest(
        &topo,
        qwen25_7b(),
        Workload::new(1, 8, 4096),
        Policy::CxlAware { striping: false },
    );
    assert_ne!(naive, ours);
}
