//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! lowers the JAX/Pallas model to HLO text) and the Rust runtime.
//!
//! `artifacts/manifest.json` maps entry-point names to HLO files plus input
//! and output specs (flattened pytree leaves, in call order):
//!
//! ```json
//! {
//!   "model": {"layers": 2, "hidden": 128, ...},
//!   "entries": {
//!     "block_fwd": {
//!       "file": "block_fwd.hlo.txt",
//!       "inputs":  [{"name": "x", "shape": [4, 64, 128], "dtype": "f32"}, ...],
//!       "outputs": [{"name": "y", "shape": [4, 64, 128], "dtype": "f32"}]
//!     }
//!   }
//! }
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor spec (flattened leaf).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered entry point.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Model metadata (architecture dims used at lowering time).
    pub model_meta: BTreeMap<String, f64>,
    pub entries: BTreeMap<String, Entry>,
}

fn parse_spec(j: &Json) -> Result<TensorSpec> {
    let name = j
        .path(&["name"])
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string();
    let shape = j
        .path(&["shape"])
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor spec missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .path(&["dtype"])
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
        .to_string();
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let mut model_meta = BTreeMap::new();
        if let Some(meta) = j.path(&["model"]).and_then(Json::as_obj) {
            for (k, v) in meta.iter() {
                if let Some(n) = v.as_f64() {
                    model_meta.insert(k.to_string(), n);
                }
            }
        }
        let entries_json = j
            .path(&["entries"])
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest.json missing 'entries'"))?;
        let mut entries = BTreeMap::new();
        for (name, e) in entries_json.iter() {
            let file = e
                .path(&["file"])
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry {name} missing file"))?;
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                e.path(&[key])
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry {name} missing {key}"))?
                    .iter()
                    .map(parse_spec)
                    .collect()
            };
            entries.insert(
                name.to_string(),
                Entry {
                    name: name.to_string(),
                    file: dir.join(file),
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest {
            dir,
            model_meta,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact entry {name:?} (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.model_meta
            .get(key)
            .map(|v| *v as usize)
            .ok_or_else(|| anyhow!("manifest model meta missing {key:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"layers": 2, "hidden": 128, "vocab": 1024},
      "entries": {
        "block_fwd": {
          "file": "block_fwd.hlo.txt",
          "inputs": [
            {"name": "x", "shape": [4, 64, 128], "dtype": "f32"},
            {"name": "wq", "shape": [128, 128], "dtype": "f32"}
          ],
          "outputs": [{"name": "y", "shape": [4, 64, 128], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.meta_usize("layers").unwrap(), 2);
        let e = m.entry("block_fwd").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![4, 64, 128]);
        assert_eq!(e.inputs[0].element_count(), 4 * 64 * 128);
        assert_eq!(e.file, PathBuf::from("/tmp/a/block_fwd.hlo.txt"));
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn rejects_empty_and_malformed() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"entries": {}}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
        let missing_shape = r#"{"entries": {"e": {"file": "f",
            "inputs": [{"name": "x", "dtype": "f32"}], "outputs": []}}}"#;
        assert!(Manifest::parse(missing_shape, PathBuf::new()).is_err());
    }
}
