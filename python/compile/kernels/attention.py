"""Pallas flash-attention (forward) with a recomputing jnp backward.

Hardware adaptation (DESIGN.md §4): the CUDA flash-attention expresses its
HBM↔shared-memory schedule with threadblocks; here the same insight is
expressed TPU-style —

* the grid iterates ``(batch·heads, q-tile)``; `BlockSpec` maps each grid
  step to one Q tile resident in VMEM,
* K/V stream through VMEM in ``block_k``-sized slices inside the kernel
  (``pl.ds`` on the K/V refs — the manual double-buffer),
* the online-softmax state ``(m, l, acc)`` stays in registers/VMEM, so
  per-step VMEM footprint is ``bq·d + 2·bk·d + bq·bk`` floats instead of
  the full ``C²`` score matrix,
* both matmuls (``q·kᵀ`` and ``p·v``) are MXU-shaped (tiles padded to the
  128-lane grain when the model dims allow).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime. Real-TPU performance is *estimated*
(DESIGN.md §8), not measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, scale):
    """One (bh, q-tile) grid step of the online-softmax attention."""
    bq = q_ref.shape[1]
    d = q_ref.shape[2]
    seq = k_ref.shape[1]
    qi = pl.program_id(1)

    q = q_ref[0, :, :].astype(jnp.float32) * scale  # [bq, d] in VMEM

    n_k = seq // block_k

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        # stream one K/V tile HBM→VMEM
        k = k_ref[0, pl.ds(ki * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(ki * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # [bq, bk] — MXU matmul
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, acc0))
    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def _pick_block(seq, want):
    """Largest divisor of `seq` that is ≤ `want` (shape-agnostic tiling)."""
    b = min(want, seq)
    while seq % b != 0:
        b -= 1
    return b


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, block_q=128, block_k=128):
    """Flash attention over ``[bh, seq, head_dim]`` tensors."""
    return _flash_fwd_only(q, k, v, causal, block_q, block_k)


def _flash_fwd_only(q, k, v, causal, block_q, block_k):
    bh, seq, d = q.shape
    assert k.shape == (bh, seq, d) and v.shape == (bh, seq, d)
    bq = _pick_block(seq, block_q)
    bk = _pick_block(seq, block_k)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attn_kernel, block_k=bk, causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, seq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),   # Q tile
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),  # K (streamed)
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),  # V (streamed)
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)


def _flash_fwd_vjp(q, k, v, causal, block_q, block_k):
    out = _flash_fwd_only(q, k, v, causal, block_q, block_k)
    return out, (q, k, v)


def _flash_bwd_vjp(causal, block_q, block_k, res, g):
    # Recomputing backward through the jnp oracle — the standard gradient-
    # checkpointing trade: no residual score matrix is ever stored by fwd.
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.attention(q, k, v, causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd_vjp, _flash_bwd_vjp)


def vmem_floats_per_step(seq, d, block_q=128, block_k=128):
    """Estimated VMEM working set (in f32 elements) of one grid step —
    the §8 structural perf metric (compare against seq² for naive)."""
    bq = _pick_block(seq, block_q)
    bk = _pick_block(seq, block_k)
    return bq * d + 2 * bk * d + bq * bk + 2 * bq + bq * d
