//! Synthetic corpus for the end-to-end fine-tuning example.
//!
//! Documents follow a noisy affine bigram process: within a document,
//! `token[t+1] = (a·token[t] + b) mod V` for per-document `(a, b)` drawn
//! from a small fixed family, with an `noise` chance of a uniform random
//! token. The successor is predictable given the current token once the
//! model infers the family — so cross-entropy drops well below `ln V` within
//! a few hundred steps, giving the loss curve EXPERIMENTS.md records.

use crate::util::prng::Xoshiro256pp;

/// Corpus generator.
pub struct CorpusGen {
    vocab: usize,
    /// Tokens are drawn from `[0, active)` — a small slice of the vocab so
    /// each (token → successor) pair is seen many times within a few
    /// hundred steps (the model still pays full-vocab softmax cost).
    active: usize,
    noise: f64,
    /// The small family of affine rules documents are drawn from.
    rules: Vec<(u64, u64)>,
    rng: Xoshiro256pp,
}

impl CorpusGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 8);
        let active = vocab.min(64);
        let mut rng = Xoshiro256pp::seeded(seed);
        // odd multipliers → bijective maps for even `active`
        let rules = (0..4)
            .map(|_| {
                (
                    rng.range_u64(1, active as u64 / 2) * 2 + 1,
                    rng.below(active as u64),
                )
            })
            .collect();
        Self {
            vocab,
            active,
            noise: 0.02,
            rules,
            rng,
        }
    }

    /// Widen/narrow the active token range.
    pub fn with_active(mut self, active: usize) -> Self {
        assert!(active >= 8 && active <= self.vocab);
        self.active = active;
        self
    }

    pub fn with_noise(mut self, noise: f64) -> Self {
        assert!((0.0..1.0).contains(&noise));
        self.noise = noise;
        self
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample one `[batch, context]` pair of (inputs, next-token labels).
    pub fn batch(&mut self, batch: usize, context: usize) -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(batch * context);
        let mut labels = Vec::with_capacity(batch * context);
        for _ in 0..batch {
            let (a, b) = *self.rng.choice(&self.rules);
            let mut tok = self.rng.below(self.active as u64);
            let mut seq = Vec::with_capacity(context + 1);
            seq.push(tok);
            for _ in 0..context {
                tok = if self.rng.chance(self.noise) {
                    self.rng.below(self.active as u64)
                } else {
                    (a.wrapping_mul(tok).wrapping_add(b)) % self.active as u64
                };
                seq.push(tok);
            }
            ids.extend(seq[..context].iter().map(|&t| t as i32));
            labels.extend(seq[1..].iter().map(|&t| t as i32));
        }
        (ids, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut g = CorpusGen::new(1024, 7);
        let (ids, labels) = g.batch(4, 128);
        assert_eq!(ids.len(), 4 * 128);
        assert_eq!(labels.len(), 4 * 128);
        for &t in ids.iter().chain(labels.iter()) {
            assert!((0..1024).contains(&t));
        }
    }

    #[test]
    fn labels_are_shifted_inputs() {
        let mut g = CorpusGen::new(512, 9).with_noise(0.0);
        let (ids, labels) = g.batch(1, 64);
        // labels[t] should equal ids[t+1] within a sequence
        for t in 0..63 {
            assert_eq!(labels[t], ids[t + 1]);
        }
    }

    #[test]
    fn successor_is_deterministic_without_noise() {
        // given the rule, token t fully determines token t+1
        let mut g = CorpusGen::new(256, 11).with_noise(0.0);
        let (ids, labels) = g.batch(8, 32);
        // build per-sequence successor maps and check consistency
        for s in 0..8 {
            let mut succ = std::collections::HashMap::new();
            for t in 0..32 {
                let cur = ids[s * 32 + t];
                let nxt = labels[s * 32 + t];
                if let Some(prev) = succ.insert(cur, nxt) {
                    assert_eq!(prev, nxt, "rule not deterministic");
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CorpusGen::new(1024, 42);
        let mut b = CorpusGen::new(1024, 42);
        assert_eq!(a.batch(2, 16), b.batch(2, 16));
    }
}
