//! The frozen pre-`simcore` fleet event loop, kept as a differential
//! oracle (DESIGN.md §14) — the fleet twin of `sim::reference`.
//!
//! This is the serial `BinaryHeap<Reverse<(u64, u8, u64, usize)>>` loop
//! exactly as it shipped in PRs 5/7, before `fleet::sim` was ported onto
//! the shared `simcore` primitives (`EventKey`/`EventQueue`, interned
//! probe memos, fixpoint-elided scheduling passes). Every scheduling pass
//! here re-clones the topology and re-builds memory plans from scratch —
//! deliberately: slow and obviously-correct is the point of an oracle.
//!
//! `rust/tests/simcore_parity.rs` and `benches/fleet_scale.rs` drive
//! [`ref_simulate_fleet_faulted`] against the production loop and demand
//! byte-identical [`FleetResult::digest`]s; the bench additionally records
//! the events/sec ratio between the two. **Do not optimize this file.**
//! Behavioral changes belong in `fleet::sim` with a matching parity
//! argument; this copy only ever changes if the *contract* changes.
//!
//! Shared leaves (`Calibrator`, `resolve_cfg`, `migration_bandwidth`,
//! `describe_fault`) are imported from `fleet::sim` — they are pure value
//! functions that the port did not touch, so sharing them cannot mask a
//! drift in the loop itself.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use super::faults::{self, Degradation, FaultKind, FaultTrace, RecoveryAction, RecoveryRef};
use super::host::FleetHost;
use super::job::{FleetTrace, JobSpec};
use super::metrics::{FleetResult, JobRecord, JobStatus, OccupancySample};
use super::scheduler::{AdmissionProbe, PolicyRef, PLACEMENT_AWARE_ALTERNATIVES};
use super::sim::{describe_fault, migration_bandwidth, resolve_cfg, CalCost, Calibrator};
use crate::offload::{MemoryPlan, PlanReservation};
use crate::topology::SystemTopology;

/// A recorded admission decision of one scheduling pass.
struct ProbeAdmission {
    engine: String,
    reservation: PlanReservation,
    cost: CalCost,
}

/// The frozen admission probe: a working free view that real `MemoryPlan`
/// builds are checked against and debited from, with the original
/// string-keyed blocked-set memo. See `fleet::sim` for the full
/// commentary; this copy exists so the oracle never borrows production
/// probe machinery.
struct Probe<'a, 't> {
    view: SystemTopology,
    base: &'a SystemTopology,
    deg_key: &'a str,
    free: Vec<u64>,
    free_gpus: usize,
    queue: Vec<&'a JobSpec>,
    cal: &'a mut Calibrator<'t>,
    blocked: &'a mut BTreeSet<String>,
    admissions: Vec<Option<ProbeAdmission>>,
    reasons: Vec<Option<String>>,
}

impl<'a, 't> Probe<'a, 't> {
    fn new(
        topo: &'a SystemTopology,
        free: Vec<u64>,
        free_gpus: usize,
        queue: Vec<&'a JobSpec>,
        cal: &'a mut Calibrator<'t>,
        blocked: &'a mut BTreeSet<String>,
        deg_key: &'a str,
    ) -> Self {
        let n = queue.len();
        Self {
            view: topo.clone(),
            base: topo,
            deg_key,
            free,
            free_gpus,
            queue,
            cal,
            blocked,
            admissions: (0..n).map(|_| None).collect(),
            reasons: (0..n).map(|_| None).collect(),
        }
    }

    fn note(&mut self, idx: usize, msg: String) {
        if self.reasons[idx].is_none() {
            self.reasons[idx] = Some(msg);
        }
    }
}

impl AdmissionProbe for Probe<'_, '_> {
    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn job(&self, idx: usize) -> &JobSpec {
        self.queue[idx]
    }

    fn try_admit(&mut self, idx: usize, engine_name: Option<&str>, lifetime: bool) -> bool {
        if self.admissions[idx].is_some() {
            return false;
        }
        let spec = self.queue[idx];
        let engine_name = engine_name.unwrap_or(&spec.engine).to_string();
        let probe_key = format!(
            "{}|{engine_name}|{lifetime}|{}",
            spec.config_key(),
            self.deg_key
        );
        if self.blocked.contains(&probe_key) {
            return false;
        }
        if spec.gpus > self.free_gpus {
            self.blocked.insert(probe_key);
            self.note(
                idx,
                format!("wants {} GPUs, {} free", spec.gpus, self.free_gpus),
            );
            return false;
        }
        let admissible = self.cal.profiles(spec).zip(resolve_cfg(spec, &engine_name));
        let Some((profiles, cfg)) = admissible else {
            self.blocked.insert(probe_key);
            self.note(
                idx,
                format!("{engine_name}: model/schedule/engine does not resolve or cannot be profiled"),
            );
            return false;
        };
        for (node, cap) in self.view.mem_nodes.iter_mut().zip(&self.free) {
            node.capacity = *cap;
        }
        let plan = match MemoryPlan::build_with_profiles(&self.view, &cfg, lifetime, profiles) {
            Ok(p) => p,
            Err(e) => {
                self.blocked.insert(probe_key);
                self.note(idx, format!("{engine_name}: {e}"));
                return false;
            }
        };
        let reservation = plan.reservation();
        drop(plan);
        let Some(cost) = self.cal.cost_on(self.base, self.deg_key, spec, &engine_name) else {
            self.blocked.insert(probe_key);
            self.note(idx, format!("{engine_name}: calibration failed"));
            return false;
        };
        for (n, b) in &reservation.parts {
            debug_assert!(self.free[n.0] >= *b, "probe view over-promised");
            self.free[n.0] -= *b;
        }
        self.free_gpus -= spec.gpus;
        self.admissions[idx] = Some(ProbeAdmission {
            engine: engine_name,
            reservation,
            cost,
        });
        true
    }
}

/// The frozen reject-at-arrival feasibility check: can the policy place
/// this job on an EMPTY host as currently degraded?
fn feasible_on_empty(
    topo: &SystemTopology,
    spec: &JobSpec,
    policy: &PolicyRef,
    cal: &mut Calibrator<'_>,
    deg_key: &str,
) -> Option<String> {
    let free: Vec<u64> = topo.mem_nodes.iter().map(|n| n.capacity).collect();
    let mut blocked = BTreeSet::new();
    let mut probe = Probe::new(
        topo,
        free,
        topo.gpus.len(),
        vec![spec],
        cal,
        &mut blocked,
        deg_key,
    );
    policy.schedule(&mut probe);
    if probe.admissions[0].is_some() {
        None
    } else {
        Some(probe.reasons[0].clone().unwrap_or_else(|| {
            "no registered engine can place the job on an empty host".to_string()
        }))
    }
}

const EV_COMPLETE: u8 = 0;
const EV_FAULT: u8 = 1;
const EV_ARRIVE: u8 = 2;
const EV_REQUEUE: u8 = 3;

const NO_COMPLETION: u64 = u64::MAX;

/// Mutable per-job lifecycle state (frozen copy).
struct JobState {
    status: JobStatus,
    engine_used: Option<String>,
    start_s: Option<f64>,
    finish_s: Option<f64>,
    iter_s: Option<f64>,
    reason: Option<String>,
    durable_iters: u64,
    run_iters: u64,
    pending_finish_s: f64,
    interruptions: u32,
    migrations: u32,
    recovery_s: f64,
    lost_tokens: u64,
    processed_iters: u64,
}

impl JobState {
    fn fresh() -> Self {
        JobState {
            status: JobStatus::Queued,
            engine_used: None,
            start_s: None,
            finish_s: None,
            iter_s: None,
            reason: None,
            durable_iters: 0,
            run_iters: 0,
            pending_finish_s: 0.0,
            interruptions: 0,
            migrations: 0,
            recovery_s: 0.0,
            lost_tokens: 0,
            processed_iters: 0,
        }
    }
}

/// Frozen fault-free entry point: the oracle twin of
/// `fleet::sim::simulate_fleet`.
pub fn ref_simulate_fleet(
    topo: &SystemTopology,
    trace: &FleetTrace,
    policy: &PolicyRef,
    threads: usize,
) -> FleetResult {
    let recovery = faults::by_name("fail-stop").expect("registered");
    ref_simulate_fleet_faulted(topo, trace, policy, &FaultTrace::empty(), &recovery, threads)
}

/// The frozen pre-port event loop: verbatim behavior of the PR 5/PR 7
/// `simulate_fleet_faulted`, including its per-event topology clones and
/// unconditional scheduling passes. See `fleet::sim` for the semantics
/// commentary; only mechanical notes live here.
pub fn ref_simulate_fleet_faulted(
    topo: &SystemTopology,
    trace: &FleetTrace,
    policy: &PolicyRef,
    faults: &FaultTrace,
    recovery: &RecoveryRef,
    threads: usize,
) -> FleetResult {
    let mut ids = BTreeSet::new();
    for j in &trace.jobs {
        assert!(ids.insert(j.id), "duplicate job id {}", j.id);
        assert!(
            j.arrival_s.is_finite() && j.arrival_s >= 0.0,
            "job {}: arrival must be a non-negative finite time",
            j.id
        );
        assert!(j.iterations >= 1, "job {}: needs at least one iteration", j.id);
        assert!(
            j.gpus >= 1 && j.batch >= 1 && j.context >= 1,
            "job {}: workload dimensions must be positive",
            j.id
        );
    }
    faults
        .validate(topo)
        .unwrap_or_else(|e| panic!("invalid fault trace: {e}"));
    let id_to_idx: BTreeMap<u64, usize> =
        trace.jobs.iter().enumerate().map(|(i, j)| (j.id, i)).collect();
    let mut cal = Calibrator::new(topo);
    cal.prewarm(&trace.jobs, threads);
    let mut host = FleetHost::new(topo);
    let mut jobs: Vec<JobState> = trace.jobs.iter().map(|_| JobState::fresh()).collect();

    let mut heap: BinaryHeap<Reverse<(u64, u8, u64, usize)>> = BinaryHeap::new();
    for (i, s) in trace.jobs.iter().enumerate() {
        heap.push(Reverse(((s.arrival_s + 0.0).to_bits(), EV_ARRIVE, i as u64, i)));
    }
    let mut seq: u64 = trace.jobs.len() as u64;
    for (fi, ev) in faults.events.iter().enumerate() {
        heap.push(Reverse(((ev.t_s + 0.0).to_bits(), EV_FAULT, seq, fi)));
        seq += 1;
    }

    let mut completion_seq: Vec<u64> = vec![NO_COMPLETION; trace.jobs.len()];

    let mut deg = Degradation::pristine(topo);
    let mut deg_key = String::new();
    let mut dtopo: Option<SystemTopology> = None;

    let mut queue: Vec<usize> = Vec::new();
    let mut samples: Vec<OccupancySample> = Vec::new();
    let mut feasible: BTreeMap<String, Option<String>> = BTreeMap::new();
    let mut blocked: BTreeSet<String> = BTreeSet::new();
    let mut n_events: u64 = 0;
    let mut running: usize = 0;

    while let Some(Reverse((tb, kind, ev_seq, ji))) = heap.pop() {
        if kind == EV_COMPLETE && completion_seq[ji] != ev_seq {
            continue;
        }
        let now = f64::from_bits(tb);
        n_events += 1;
        match kind {
            EV_COMPLETE => {
                let spec = &trace.jobs[ji];
                host.release(spec.id, spec.gpus)
                    .unwrap_or_else(|e| panic!("completion of job {}: {e}", spec.id));
                completion_seq[ji] = NO_COMPLETION;
                jobs[ji].processed_iters += jobs[ji].run_iters;
                jobs[ji].status = JobStatus::Completed;
                jobs[ji].finish_s = Some(now);
                running -= 1;
                blocked.clear();
            }
            EV_FAULT => {
                let ev = &faults.events[ji];
                deg.apply(&ev.kind);
                deg_key = deg.key();
                dtopo = if deg.is_pristine() {
                    None
                } else {
                    Some(deg.degraded_topo(topo))
                };
                let eff = deg.effective_caps(topo);
                for (i, cap) in eff.iter().enumerate() {
                    host.set_capacity(i, *cap);
                }
                blocked.clear();
                let desc = describe_fault(topo, &ev.kind);

                let victims: Vec<(usize, u64)> = match &ev.kind {
                    FaultKind::NodeOffline { node } => host
                        .residents_on(*node)
                        .into_iter()
                        .map(|(id, bytes)| (id_to_idx[&id], bytes))
                        .collect(),
                    FaultKind::CapacitySqueeze { node, .. } => {
                        let used = host.used()[*node];
                        if used > eff[*node] {
                            let mut residents = host.residents_on(*node);
                            residents.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                            let mut overshoot = used - eff[*node];
                            let mut v = Vec::new();
                            for (id, bytes) in residents {
                                if overshoot == 0 {
                                    break;
                                }
                                v.push((id_to_idx[&id], bytes));
                                overshoot = overshoot.saturating_sub(bytes);
                            }
                            v
                        } else {
                            Vec::new()
                        }
                    }
                    FaultKind::LinkDegrade { .. } | FaultKind::NodeRestore { .. } => Vec::new(),
                };

                for &(vji, _) in &victims {
                    host.release_memory(trace.jobs[vji].id)
                        .unwrap_or_else(|e| panic!("fault victim: {e}"));
                }
                let cur = dtopo.as_ref().unwrap_or(topo);
                for (vji, bytes_hit) in victims {
                    let spec = &trace.jobs[vji];
                    let tpi = spec.workload().tokens_per_iter();
                    let st = &mut jobs[vji];
                    let iter_s = st.iter_s.expect("victim was running");
                    let remaining =
                        ((st.pending_finish_s - now) / iter_s).ceil().max(0.0) as u64;
                    let run_done = st.run_iters.saturating_sub(remaining);
                    st.interruptions += 1;
                    let hit = st.interruptions;
                    let action = recovery.decide(spec, hit);
                    let mut eff_action = action;
                    if action == RecoveryAction::Evacuate {
                        let free = host.free();
                        let mut view = cur.clone();
                        for (node, cap) in view.mem_nodes.iter_mut().zip(&free) {
                            node.capacity = *cap;
                        }
                        let mut candidates: Vec<String> = vec![st
                            .engine_used
                            .clone()
                            .unwrap_or_else(|| spec.engine.clone())];
                        for alt in PLACEMENT_AWARE_ALTERNATIVES {
                            if !candidates.iter().any(|c| c == alt) {
                                candidates.push(alt.to_string());
                            }
                        }
                        let mut placed: Option<(String, PlanReservation)> = None;
                        'search: for engine_name in &candidates {
                            let Some((profiles, cfg)) =
                                cal.profiles(spec).zip(resolve_cfg(spec, engine_name))
                            else {
                                continue;
                            };
                            for lifetime in [false, true] {
                                if let Ok(plan) = MemoryPlan::build_with_profiles(
                                    &view,
                                    &cfg,
                                    lifetime,
                                    profiles.clone(),
                                ) {
                                    placed = Some((engine_name.clone(), plan.reservation()));
                                    break 'search;
                                }
                            }
                        }
                        if let Some((engine_name, resv)) = placed {
                            host.reserve_memory(spec.id, &resv)
                                .expect("plan was built against the free view");
                            let migrate_s = bytes_hit as f64 / migration_bandwidth(cur);
                            st.pending_finish_s += migrate_s;
                            heap.push(Reverse((
                                st.pending_finish_s.to_bits(),
                                EV_COMPLETE,
                                seq,
                                vji,
                            )));
                            completion_seq[vji] = seq;
                            seq += 1;
                            st.status = JobStatus::Migrated;
                            st.migrations += 1;
                            st.recovery_s += migrate_s;
                            st.engine_used = Some(engine_name);
                            continue;
                        }
                        eff_action = RecoveryAction::CheckpointRestart;
                    }
                    st.processed_iters += run_done;
                    host.release_gpus(spec.gpus);
                    running -= 1;
                    completion_seq[vji] = NO_COMPLETION;
                    if eff_action == RecoveryAction::CheckpointRestart
                        && hit <= faults::MAX_RETRIES
                    {
                        let total_done = st.durable_iters + run_done;
                        let ckpt = (total_done / faults::CHECKPOINT_INTERVAL_ITERS)
                            * faults::CHECKPOINT_INTERVAL_ITERS;
                        st.lost_tokens += (total_done - ckpt) * tpi;
                        st.durable_iters = ckpt;
                        st.status = JobStatus::Interrupted;
                        let backoff = faults::BACKOFF_BASE_S * 2f64.powi(hit as i32 - 1);
                        heap.push(Reverse(((now + backoff).to_bits(), EV_REQUEUE, seq, vji)));
                        seq += 1;
                    } else {
                        st.status = JobStatus::Failed;
                        st.finish_s = Some(now);
                        st.lost_tokens = st.processed_iters * tpi;
                        st.reason = Some(if action == RecoveryAction::FailStop {
                            format!("fail-stop: {desc}")
                        } else {
                            format!("retries exhausted after {desc}")
                        });
                    }
                }
            }
            EV_ARRIVE => {
                let spec = &trace.jobs[ji];
                let key = format!("{}|{}|{deg_key}", spec.config_key(), spec.engine);
                let cur = dtopo.as_ref().unwrap_or(topo);
                let verdict = match feasible.get(&key) {
                    Some(v) => v.clone(),
                    None => {
                        let v = feasible_on_empty(cur, spec, policy, &mut cal, &deg_key);
                        feasible.insert(key, v.clone());
                        v
                    }
                };
                match verdict {
                    None => queue.push(ji),
                    Some(reason) => {
                        jobs[ji].status = JobStatus::Rejected;
                        jobs[ji].reason = Some(reason);
                    }
                }
            }
            EV_REQUEUE => {
                jobs[ji].status = JobStatus::Queued;
                queue.push(ji);
            }
            _ => unreachable!("unknown event kind {kind}"),
        }

        // The frozen loop runs an unconditional scheduling pass after
        // EVERY event — the production loop elides provable no-op passes;
        // the parity suite exists to show the elision is invisible.
        let cur = dtopo.as_ref().unwrap_or(topo);
        let snapshot: Vec<&JobSpec> = queue.iter().map(|&i| &trace.jobs[i]).collect();
        let mut probe = Probe::new(
            cur,
            host.free(),
            host.free_gpus(),
            snapshot,
            &mut cal,
            &mut blocked,
            &deg_key,
        );
        policy.schedule(&mut probe);
        let admissions = probe.admissions;
        let mut started: Vec<usize> = Vec::new();
        for (qpos, adm) in admissions.into_iter().enumerate() {
            let Some(adm) = adm else { continue };
            let ji = queue[qpos];
            let spec = &trace.jobs[ji];
            host.reserve(spec.id, &adm.reservation, spec.gpus)
                .expect("probe debited the identical free view");
            let remaining = spec.iterations as u64 - jobs[ji].durable_iters;
            let finish = now + adm.cost.iter_s * remaining as f64;
            jobs[ji].status = JobStatus::Running;
            jobs[ji].engine_used = Some(adm.engine);
            if jobs[ji].start_s.is_none() {
                jobs[ji].start_s = Some(now);
            }
            jobs[ji].iter_s = Some(adm.cost.iter_s);
            jobs[ji].run_iters = remaining;
            jobs[ji].pending_finish_s = finish;
            heap.push(Reverse((finish.to_bits(), EV_COMPLETE, seq, ji)));
            completion_seq[ji] = seq;
            seq += 1;
            running += 1;
            started.push(qpos);
        }
        for &qpos in started.iter().rev() {
            queue.remove(qpos);
        }
        samples.push(OccupancySample {
            t_s: now,
            used: host.used(),
            queue_len: queue.len(),
            running,
        });
    }
    assert!(running == 0, "fleet failed to drain: {running} still running");
    if !queue.is_empty() {
        assert!(
            !faults.events.is_empty(),
            "fleet failed to drain with no faults: {} queued",
            queue.len()
        );
        for ji in queue {
            let spec = &trace.jobs[ji];
            let tpi = spec.workload().tokens_per_iter();
            jobs[ji].status = JobStatus::Failed;
            jobs[ji].reason =
                Some("starved on the degraded host after the trace drained".to_string());
            jobs[ji].lost_tokens = jobs[ji].processed_iters * tpi;
        }
    }

    let mut result = FleetResult::new(policy.name(), topo);
    result.recovery = recovery.name().to_string();
    result.n_events = n_events;
    result.n_faults = faults.events.len() as u64;
    result.samples = samples;
    result.records = trace
        .jobs
        .iter()
        .zip(jobs)
        .map(|(spec, j)| {
            let tpi = spec.workload().tokens_per_iter();
            JobRecord {
                id: spec.id,
                model: spec.model.clone(),
                gpus: spec.gpus,
                batch: spec.batch,
                context: spec.context,
                schedule: spec.schedule.clone(),
                engine_requested: spec.engine.clone(),
                engine_used: j.engine_used,
                iterations: spec.iterations,
                arrival_s: spec.arrival_s,
                start_s: j.start_s,
                finish_s: j.finish_s,
                iter_s: j.iter_s,
                total_tokens: spec.total_tokens(),
                status: j.status,
                reason: j.reason,
                interruptions: j.interruptions,
                migrations: j.migrations,
                recovery_s: j.recovery_s,
                lost_tokens: j.lost_tokens,
                processed_tokens: j.processed_iters * tpi,
            }
        })
        .collect();
    result
}
