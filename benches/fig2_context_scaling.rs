//! Fig. 2: 12B model, batch 5, 2 GPUs — throughput and CPU memory
//! requirement vs context length (512 … 32K).
//!
//! Paper shape: memory grows linearly with C (activation checkpoints);
//! throughput in tokens/s grows as longer contexts amortize the fixed
//! parameter-streaming + optimizer cost.

use cxlfine::mem::Policy;
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::mistral_nemo_12b;
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::topology::presets::config_a;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let mut report = BenchReport::new("fig2_context_scaling");
    // Use the CXL-aware plan on Config A so every cell fits (the paper ran
    // on 512 GB DRAM + 512 GB AIC; pure DRAM OOMs at the top end).
    let topo = config_a();
    let model = mistral_nemo_12b();
    let mut t = Table::new(&["context", "cpu_mem_gib", "tokens_per_sec", "iter_s"]);
    let mut xs = Vec::new();
    let mut mem = Vec::new();
    let mut tps = Vec::new();
    for c in [512usize, 1024, 2048, 4096, 8192, 16384, 32768] {
        let w = Workload::new(2, 5, c);
        let f = Footprint::compute(&model, &w);
        let cfg = RunConfig::new(model.clone(), w, Policy::CxlAware { striping: false });
        let plan = MemoryPlan::build(&topo, &cfg).expect("plan fits on config A");
        let b = simulate_iteration(&topo, &cfg, &plan);
        let gib = f.total() as f64 / GIB as f64;
        t.row(trow![
            c,
            format!("{gib:.1}"),
            format!("{:.0}", b.tokens_per_sec()),
            format!("{:.2}", b.iter_s)
        ]);
        xs.push(c as f64);
        mem.push(gib);
        tps.push(b.tokens_per_sec());
    }
    // paper shape: memory linear in C — check the last doubling is ~2× the
    // activation delta
    let slope1 = (mem[6] - mem[5]) / (32768.0 - 16384.0);
    let slope2 = (mem[5] - mem[4]) / (16384.0 - 8192.0);
    assert!(
        (slope1 / slope2 - 1.0).abs() < 0.05,
        "memory not linear in C: slopes {slope1:.4} vs {slope2:.4}"
    );
    report.section(
        "mem_and_throughput_vs_context",
        t,
        points_json(&xs, &[("cpu_mem_gib", &mem), ("tokens_per_sec", &tps)]),
    );
    report.finish();
}
