//! The incremental sweep engine's shared evaluation cache.
//!
//! A grid sweep decomposes into three pure passes per (cell, engine,
//! schedule) combination — profile probe, plan build, schedule build +
//! DES run — and every pass is a pure function of a small digestible key:
//!
//! * **probe** — [`MemoryPlan::profile_run`] depends on the config alone
//!   (placement-independent, pinned by `profiles_are_placement_independent`),
//!   so its memo key is `(cfg-dims, topo)` with the engine *excluded*;
//! * **plan** — [`MemoryPlan::build`] depends on `(cfg-dims, engine,
//!   topo)`. The memo stores the plan's *shape digest* (or the
//!   [`super::plan::PlanError`] reason for OOM cells), not the plan itself
//!   — plans borrow the topology and are cheap to rebuild on the rare
//!   cache path that needs one (a schedule miss);
//! * **schedule / exec** — builders are pure functions of `(topo, cfg,
//!   plan)` and read the plan only through placement observables (layouts,
//!   fractions, footprint), so `(schedule, cfg-dims, plan-shape, topo)`
//!   keys both the built DAG and its executed [`PhaseBreakdown`].
//!
//! Because every memoized value is *value-pure* (the cache can only
//! substitute a bitwise-equal result), sweep output is invariant in cache
//! state, worker count, and evaluation order — the property the
//! `sweep_incremental` suite and the `sweep_scale` bench pin.
//!
//! DES runs draw on a per-worker thread-local [`FlowSim`] arena through
//! [`crate::offload::executor::execute_reusing`] (tracing off), so the
//! hot path re-allocates neither the simulator slabs nor the span
//! strings. An [`EvalCtx`] is the sweep-layer sibling of the fleet
//! simulator's `Calibrator`/`ProbeCtx`, and all four memo layers share
//! one implementation: [`crate::util::memo::Memo`].

use std::cell::RefCell;
use std::sync::{Arc, Mutex};

use super::executor::execute_reusing;
use super::metrics::PhaseBreakdown;
use super::plan::{MemoryPlan, RunConfig, RunProfiles};
use super::schedule::Schedule;
use super::schedules::ScheduleRef;
use crate::mem::EngineRef;
use crate::model::footprint::Workload;
use crate::model::ModelConfig;
use crate::sim::flow::FlowSim;
use crate::sim::memmodel::AccessMode;
use crate::topology::{MemKind, SystemTopology};
use crate::util::digest::Fnv64;
use crate::util::memo::Memo;

/// Probe memo key: `(cfg-dims digest, topo digest)` — no engine.
type ProbeKey = (u64, u64);
/// Plan memo key: `(cfg-dims digest, engine name, topo digest)`.
type PlanKey = (u64, String, u64);
/// Schedule / exec memo key:
/// `(schedule name, cfg-dims digest, plan-shape digest, topo digest)`.
type SchedKey = (String, u64, u64, u64);

/// Digest of every timing-relevant topology field. Two topologies with
/// equal digests produce bitwise-equal simulations, so the digest stands
/// in for the topology in every memo key.
pub fn topo_digest(topo: &SystemTopology) -> u64 {
    let mut h = Fnv64::new();
    h.write_str(&topo.name);
    h.write_str(&topo.cpu.name)
        .write_u64(topo.cpu.cores as u64)
        .write_u64(topo.cpu.llc_bytes)
        .write_f64(topo.cpu.adam_compute_ns_per_elem)
        .write_u64(topo.cpu.optimizer_threads as u64);
    h.write_u64(topo.mem_nodes.len() as u64);
    for n in &topo.mem_nodes {
        h.write_str(&n.name)
            .write_u64(match n.kind {
                MemKind::LocalDram => 0,
                MemKind::CxlAic => 1,
            })
            .write_u64(n.capacity)
            .write_f64(n.latency_ns)
            .write_f64(n.peak_bw)
            .write_f64(n.cpu_stream_bw);
        match n.link {
            None => h.write_u64(0),
            Some(l) => h.write_u64(1).write_u64(l.0 as u64),
        };
    }
    h.write_u64(topo.links.len() as u64);
    for l in &topo.links {
        h.write_str(&l.name)
            .write_f64(l.per_dir_bw)
            .write_f64(l.single_stream_eff)
            .write_f64(l.contended_eff);
    }
    h.write_u64(topo.gpus.len() as u64);
    for g in &topo.gpus {
        h.write_str(&g.name)
            .write_f64(g.bf16_flops)
            .write_f64(g.mfu)
            .write_u64(g.hbm_bytes)
            .write_u64(g.link.0 as u64);
    }
    h.finish()
}

/// Digest of every run dimension except the placement engine: model
/// shape, workload, prefetch depth, and the config's *own* schedule name
/// (the one the plan builder profiles against). Engines key the plan
/// memo separately; the swept schedule keys the exec memo separately.
pub fn cfg_key(cfg: &RunConfig) -> u64 {
    let mut h = Fnv64::new();
    h.write_str(&cfg.model.name)
        .write_u64(cfg.model.layers as u64)
        .write_u64(cfg.model.hidden as u64)
        .write_u64(cfg.model.heads as u64)
        .write_u64(cfg.model.kv_heads as u64)
        .write_u64(cfg.model.head_dim as u64)
        .write_u64(cfg.model.ffn_hidden as u64)
        .write_u64(cfg.model.vocab as u64)
        .write_u64(u64::from(cfg.model.tie_embeddings));
    h.write_u64(cfg.workload.n_gpus as u64)
        .write_u64(cfg.workload.batch as u64)
        .write_u64(cfg.workload.context as u64)
        .write_u64(cfg.prefetch_depth as u64);
    h.write_str(cfg.schedule.name());
    h.finish()
}

/// Digest of everything a schedule builder can observe in a built plan:
/// region names, exact per-node byte shards, access modes, and committed
/// lifetimes, in allocation order. Two plans with equal shape digests
/// drive builders to identical schedules (builders read plans only via
/// `opt_layout` / `region_layout` / `*_fractions` / the footprint, all of
/// which are functions of these fields plus the config).
pub fn plan_shape_digest(plan: &MemoryPlan<'_>) -> u64 {
    let mut h = Fnv64::new();
    let mut count = 0u64;
    for r in plan.alloc.regions() {
        count += 1;
        h.write_str(&r.name).write_u64(r.bytes);
        h.write_u64(r.placement.parts.len() as u64);
        for (n, b) in &r.placement.parts {
            h.write_u64(n.0 as u64).write_u64(*b);
        }
        h.write_u64(match r.placement.mode {
            AccessMode::Interleaved => 0,
            AccessMode::Partitioned => 1,
        });
        match r.lifetime {
            None => h.write_u64(0),
            Some(l) => h
                .write_u64(1)
                .write_u64(u64::from(l.birth_phase))
                .write_u64(u64::from(l.death_phase)),
        };
    }
    h.write_u64(count);
    h.finish()
}

thread_local! {
    /// Per-worker DES arena: slabs, heaps and maxmin scratch survive
    /// across runs (`FlowSim::reset` pins reuse as bitwise-fresh).
    static ARENA: RefCell<FlowSim> = RefCell::new(FlowSim::new());
}

/// Run `sched` inside the calling worker's thread-local arena, tracing
/// off. Bitwise-identical to `simulate_iteration`'s execute-and-reduce
/// (pinned by `reused_arena_without_tracing_matches_fresh_execute_bitwise`
/// and the sweep parity suite).
fn run_in_arena(topo: &SystemTopology, sched: &Schedule) -> PhaseBreakdown {
    ARENA.with(|a| {
        let sim = std::mem::replace(&mut *a.borrow_mut(), FlowSim::new());
        let (ex, sim) = execute_reusing(topo, sched, sim, false);
        *a.borrow_mut() = sim;
        ex.report.to_breakdown()
    })
}

/// Hit/miss counters of every [`EvalCtx`] memo layer, snapshotted by
/// [`EvalCtx::stats`] (printed by `cxlfine sweep` and recorded by the
/// `sweep_scale` bench).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub probe_hits: u64,
    pub probe_misses: u64,
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub sched_hits: u64,
    pub sched_misses: u64,
    pub exec_hits: u64,
    pub exec_misses: u64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.probe_hits + self.plan_hits + self.sched_hits + self.exec_hits
    }

    pub fn misses(&self) -> u64 {
        self.probe_misses + self.plan_misses + self.sched_misses + self.exec_misses
    }

    /// The one-line summary `cxlfine sweep` prints after the table.
    pub fn summary_line(&self) -> String {
        format!(
            "cache: probe {}/{} plan {}/{} sched {}/{} exec {}/{} (hits/lookups)",
            self.probe_hits,
            self.probe_hits + self.probe_misses,
            self.plan_hits,
            self.plan_hits + self.plan_misses,
            self.sched_hits,
            self.sched_hits + self.sched_misses,
            self.exec_hits,
            self.exec_hits + self.exec_misses,
        )
    }
}

/// The shared evaluation context of an incremental sweep: four interned,
/// digest-keyed memo layers behind mutexes, safe to share across sweep
/// workers and across successive sweeps (that cross-sweep reuse is the
/// ≥5× warm-path gate of `benches/sweep_scale.rs`).
#[derive(Default)]
pub struct EvalCtx {
    probes: Mutex<Memo<ProbeKey, Result<RunProfiles, String>>>,
    plans: Mutex<Memo<PlanKey, Result<u64, String>>>,
    scheds: Mutex<Memo<SchedKey, Arc<Schedule>>>,
    execs: Mutex<Memo<SchedKey, PhaseBreakdown>>,
}

impl EvalCtx {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot the per-layer hit/miss counters.
    pub fn stats(&self) -> CacheStats {
        let probes = self.probes.lock().unwrap();
        let plans = self.plans.lock().unwrap();
        let scheds = self.scheds.lock().unwrap();
        let execs = self.execs.lock().unwrap();
        CacheStats {
            probe_hits: probes.hits(),
            probe_misses: probes.misses(),
            plan_hits: plans.hits(),
            plan_misses: plans.misses(),
            sched_hits: scheds.hits(),
            sched_misses: scheds.misses(),
            exec_hits: execs.hits(),
            exec_misses: execs.misses(),
        }
    }

    /// Memoized [`MemoryPlan::profile_run`]. Keyed without the engine:
    /// one probe serves every profile-consuming engine of the same cell,
    /// and every later sweep over the same grid.
    pub fn profiles(
        &self,
        topo: &SystemTopology,
        topo_d: u64,
        cfg: &RunConfig,
        ck: u64,
    ) -> Result<RunProfiles, String> {
        let key = (ck, topo_d);
        if let Some(v) = self.probes.lock().unwrap().get(&key) {
            return v;
        }
        let v = MemoryPlan::profile_run(topo, cfg).map_err(|e| e.to_string());
        self.probes.lock().unwrap().insert(key, v.clone());
        v
    }

    /// Build `cfg`'s plan the way the legacy sweep would, except that
    /// profile-consuming engines draw on the probe memo (byte-identical
    /// plans, pinned by `build_with_profiles_matches_the_self_profiling_paths`);
    /// everything else takes the plain static path so it stays
    /// work-identical, not just byte-identical.
    fn build_plan<'t>(
        &self,
        topo: &'t SystemTopology,
        topo_d: u64,
        cfg: &RunConfig,
        ck: u64,
    ) -> Result<MemoryPlan<'t>, String> {
        if cfg.engine.uses_profiles() {
            let prof = self.profiles(topo, topo_d, cfg, ck)?;
            MemoryPlan::build_with_profiles(topo, cfg, false, prof).map_err(|e| e.to_string())
        } else {
            MemoryPlan::build(topo, cfg).map_err(|e| e.to_string())
        }
    }

    /// Evaluate one engine column of one grid cell: every schedule's
    /// breakdown, or `(all None, Some(reason))` when the plan does not
    /// fit. The warm path (all memos hit) does zero probe passes, zero
    /// plan builds, zero schedule builds, and zero DES runs; an OOM cell
    /// short-circuits on its cached plan error without re-probing.
    pub fn eval_engine_cell(
        &self,
        topo: &SystemTopology,
        topo_d: u64,
        model: &ModelConfig,
        w: Workload,
        engine: &EngineRef,
        schedules: &[ScheduleRef],
    ) -> (Vec<Option<PhaseBreakdown>>, Option<String>) {
        assert!(
            w.n_gpus <= topo.gpus.len(),
            "workload wants {} GPUs, topology has {}",
            w.n_gpus,
            topo.gpus.len()
        );
        let cfg = RunConfig::new(model.clone(), w, engine.clone());
        let ck = cfg_key(&cfg);
        let pk: PlanKey = (ck, engine.name().to_string(), topo_d);

        // The plan is rebuilt lazily: a cell whose schedules all hit the
        // exec memo never touches the allocator again.
        let mut local_plan: Option<MemoryPlan<'_>> = None;
        let plan_entry = {
            let cached = self.plans.lock().unwrap().get(&pk);
            match cached {
                Some(v) => v,
                None => {
                    let built = self.build_plan(topo, topo_d, &cfg, ck);
                    let entry = match &built {
                        Ok(p) => Ok(plan_shape_digest(p)),
                        Err(e) => Err(e.clone()),
                    };
                    self.plans.lock().unwrap().insert(pk, entry.clone());
                    if let Ok(p) = built {
                        local_plan = Some(p);
                    }
                    entry
                }
            }
        };

        let shape = match plan_entry {
            Err(reason) => return (vec![None; schedules.len()], Some(reason)),
            Ok(shape) => shape,
        };
        let mut runs = Vec::with_capacity(schedules.len());
        for sref in schedules {
            let ek: SchedKey = (sref.name().to_string(), ck, shape, topo_d);
            if let Some(b) = self.execs.lock().unwrap().get(&ek) {
                runs.push(Some(b));
                continue;
            }
            let sched: Arc<Schedule> = {
                let hit = self.scheds.lock().unwrap().get(&ek);
                match hit {
                    Some(s) => s,
                    None => {
                        if local_plan.is_none() {
                            local_plan = Some(
                                self.build_plan(topo, topo_d, &cfg, ck)
                                    .expect("plan memo says this cell fits"),
                            );
                        }
                        let plan = local_plan.as_ref().unwrap();
                        let run_cfg = cfg.clone().with_schedule(sref.clone());
                        let s = Arc::new(run_cfg.schedule.build(topo, &run_cfg, plan));
                        self.scheds.lock().unwrap().insert(ek.clone(), s.clone());
                        s
                    }
                }
            };
            let b = run_in_arena(topo, &sched);
            self.execs.lock().unwrap().insert(ek, b);
            runs.push(Some(b));
        }
        (runs, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::presets::{qwen25_7b, tiny_2m};
    use crate::offload::schedules;
    use crate::offload::simulate_iteration;
    use crate::topology::presets::{config_a, dev_tiny, with_dram_capacity};
    use crate::util::units::GIB;

    #[test]
    fn cfg_key_separates_every_dimension() {
        let base = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::DramOnly,
        );
        let k0 = cfg_key(&base);
        // Engine must NOT separate (one probe per cell serves all engines).
        let other_engine = RunConfig {
            engine: Policy::NaiveInterleave.into(),
            ..base.clone()
        };
        assert_eq!(k0, cfg_key(&other_engine));
        // Every swept dimension must.
        let mut v = base.clone();
        v.workload = Workload::new(1, 8, 8192);
        assert_ne!(k0, cfg_key(&v));
        let mut v = base.clone();
        v.workload = Workload::new(1, 4, 4096);
        assert_ne!(k0, cfg_key(&v));
        let mut v = base.clone();
        v.workload = Workload::new(2, 8, 4096);
        assert_ne!(k0, cfg_key(&v));
        let mut v = base.clone();
        v.prefetch_depth = 3;
        assert_ne!(k0, cfg_key(&v));
        let mut v = base.clone();
        v.model.layers += 1;
        assert_ne!(k0, cfg_key(&v));
        let v = base
            .clone()
            .with_schedule(schedules::by_name("lora").unwrap());
        assert_ne!(k0, cfg_key(&v));
    }

    #[test]
    fn topo_digest_tracks_capacity_and_identity() {
        let a = config_a();
        assert_eq!(topo_digest(&a), topo_digest(&config_a()));
        let shrunk = with_dram_capacity(config_a(), 128 * GIB);
        assert_ne!(topo_digest(&a), topo_digest(&shrunk));
        assert_ne!(topo_digest(&a), topo_digest(&dev_tiny()));
    }

    #[test]
    fn plan_shape_digest_tracks_placements() {
        let cxl = with_dram_capacity(config_a(), 128 * GIB);
        let cfg = |p: Policy| RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), p);
        let a = MemoryPlan::build(&cxl, &cfg(Policy::CxlAware { striping: false })).unwrap();
        let b = MemoryPlan::build(&cxl, &cfg(Policy::CxlAware { striping: false })).unwrap();
        assert_eq!(plan_shape_digest(&a), plan_shape_digest(&b));
        let n = MemoryPlan::build(&cxl, &cfg(Policy::NaiveInterleave)).unwrap();
        assert_ne!(plan_shape_digest(&a), plan_shape_digest(&n));
    }

    #[test]
    fn eval_matches_the_direct_path_bitwise_and_then_hits() {
        let topo = dev_tiny();
        let topo_d = topo_digest(&topo);
        let model = tiny_2m();
        let w = Workload::new(2, 4, 512);
        let engine: EngineRef = Policy::CxlAware { striping: false }.into();
        let scheds = vec![schedules::zero_offload(), schedules::by_name("lora").unwrap()];

        let ctx = EvalCtx::new();
        let (runs, oom) = ctx.eval_engine_cell(&topo, topo_d, &model, w, &engine, &scheds);
        assert!(oom.is_none());
        // Direct (legacy) evaluation of the same column.
        let cfg = RunConfig::new(model.clone(), w, engine.clone());
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        for (run, sref) in runs.iter().zip(&scheds) {
            let direct = {
                let cfg = cfg.clone().with_schedule(sref.clone());
                simulate_iteration(&topo, &cfg, &plan)
            };
            let got = run.expect("cell fits");
            assert_eq!(got.iter_s.to_bits(), direct.iter_s.to_bits());
            assert_eq!(got.fwd_s.to_bits(), direct.fwd_s.to_bits());
            assert_eq!(got.bwd_s.to_bits(), direct.bwd_s.to_bits());
            assert_eq!(got.step_s.to_bits(), direct.step_s.to_bits());
            assert_eq!(got.tokens, direct.tokens);
        }
        let cold = ctx.stats();
        assert_eq!(cold.exec_misses, 2);
        assert_eq!(cold.plan_misses, 1);

        // Second evaluation: pure memo traffic, identical values.
        let (again, oom) = ctx.eval_engine_cell(&topo, topo_d, &model, w, &engine, &scheds);
        assert!(oom.is_none());
        for (a, b) in runs.iter().zip(&again) {
            assert_eq!(
                a.unwrap().iter_s.to_bits(),
                b.unwrap().iter_s.to_bits()
            );
        }
        let warm = ctx.stats();
        assert_eq!(warm.exec_hits, 2);
        assert_eq!(warm.plan_hits, 1);
        assert_eq!(warm.exec_misses, cold.exec_misses, "warm pass must not miss");
        assert_eq!(warm.sched_misses, cold.sched_misses);
    }

    #[test]
    fn oom_cells_short_circuit_with_a_cached_reason() {
        let tiny = with_dram_capacity(config_a(), 8 * GIB);
        let topo_d = topo_digest(&tiny);
        let ctx = EvalCtx::new();
        let engine: EngineRef = Policy::DramOnly.into();
        let scheds = vec![schedules::zero_offload()];
        let (runs, oom) = ctx.eval_engine_cell(
            &tiny,
            topo_d,
            &qwen25_7b(),
            Workload::new(1, 8, 4096),
            &engine,
            &scheds,
        );
        assert_eq!(runs, vec![None]);
        let reason = oom.expect("OOM must carry its reason");
        // The reason is the PlanError rendering the legacy path produced.
        let cfg = RunConfig::new(qwen25_7b(), Workload::new(1, 8, 4096), engine.clone());
        let direct = MemoryPlan::build(&tiny, &cfg).unwrap_err();
        assert_eq!(reason, direct.to_string());
        // Re-evaluating hits the cached error: no second build attempt.
        let before = ctx.stats();
        let (_, oom2) = ctx.eval_engine_cell(
            &tiny,
            topo_d,
            &qwen25_7b(),
            Workload::new(1, 8, 4096),
            &engine,
            &scheds,
        );
        assert_eq!(oom2.as_deref(), Some(reason.as_str()));
        let after = ctx.stats();
        assert_eq!(after.plan_hits, before.plan_hits + 1);
        assert_eq!(after.plan_misses, before.plan_misses);
    }

    #[test]
    fn stats_summary_line_is_stable() {
        let s = CacheStats {
            probe_hits: 1,
            probe_misses: 2,
            plan_hits: 3,
            plan_misses: 4,
            sched_hits: 5,
            sched_misses: 6,
            exec_hits: 7,
            exec_misses: 8,
        };
        assert_eq!(
            s.summary_line(),
            "cache: probe 1/3 plan 3/7 sched 5/11 exec 7/15 (hits/lookups)"
        );
        assert_eq!(s.hits(), 16);
        assert_eq!(s.misses(), 20);
    }
}
