//! Deterministic parallel lanes (DESIGN.md §14).
//!
//! The fleet's `--threads` contract is *digest invariance*: any lane count
//! (including 1) must produce bit-identical results. That is achievable
//! only for **value-pure** fan-outs — closures whose result for item `i`
//! depends on `i` and captured immutable state alone, never on lane
//! assignment, interleaving, or shared mutable state. [`par_indexed`] is
//! the one sanctioned shape: results come back in item order, so the
//! caller's sequential merge (a `BTreeMap` fill, a fold) visits them in an
//! order independent of how the lanes raced.
//!
//! The fleet calibration pre-warm is the proving workload: every
//! per-(config, engine) cost cell is a pure function of the topology and
//! spec, computed on whatever lane picks it up, merged in item order.

use crate::util::threadpool::par_map;

pub use crate::util::threadpool::default_threads;

/// Run `f(0..n)` across at most `lanes` worker lanes (min 1) and return
/// the results **in item order**. `f` must be value-pure (see module
/// docs); under that contract the output is bit-identical for every lane
/// count.
pub fn par_indexed<R: Send, F: Fn(usize) -> R + Sync>(n: usize, lanes: usize, f: F) -> Vec<R> {
    par_map(n, lanes.max(1), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_lane_count_invariant_for_pure_closures() {
        // A value-pure closure with enough arithmetic that racy merges
        // would scramble it; every lane count must agree bit-for-bit.
        let f = |i: usize| {
            let x = (i as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ 0xdeadbeef;
            (i, x, (x as f64).sqrt().to_bits())
        };
        let golden = par_indexed(257, 1, f);
        for lanes in [2, 3, 4, 8] {
            assert_eq!(par_indexed(257, lanes, f), golden, "{lanes} lanes");
        }
    }

    #[test]
    fn zero_lanes_is_clamped_to_one() {
        assert_eq!(par_indexed(3, 0, |i| i * 2), vec![0, 2, 4]);
    }

    #[test]
    fn empty_fanout_is_a_noop() {
        let out: Vec<u8> = par_indexed(0, 4, |_| unreachable!());
        assert!(out.is_empty());
    }
}
