//! The schedule-graph IR: a fine-tuning iteration as a declarative task
//! DAG instead of a hand-woven state machine.
//!
//! A [`Schedule`] is a list of typed [`OpNode`]s — host↔GPU transfers, GPU
//! kernels, CPU optimizer phases, and barriers — joined by explicit
//! dependency edges and grouped under named phases. The nodes carry *model*
//! quantities (bytes, FLOPs, element counts), never wall-clock times: the
//! [`crate::offload::executor`] prices them against a [`crate::topology::
//! SystemTopology`] when it walks the graph over the fabric.
//!
//! Determinism contract (DESIGN.md §9): node indices are the executor's
//! dispatch priority — whenever several nodes become runnable from the same
//! completion event they are issued in ascending [`OpId`] order, so a
//! builder that lists nodes in the legacy engine's issuance order
//! reproduces the legacy event stream byte-for-byte. Builders for new
//! scenarios only need *some* fixed order; parity-critical builders
//! (`schedules::zero_offload`) document theirs.

use crate::mem::RegionId;
use crate::sim::fabric::Dir;
use crate::sim::memmodel::OptLayout;
use crate::topology::{GpuId, NodeId, SystemTopology};

/// Index of a node inside one [`Schedule`] (also its dispatch priority and
/// its event tag in the executor).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OpId(pub u32);

/// One FLOPs contribution to a GPU kernel: `scale · (flops / gpu_flops)`
/// seconds. Kernels are sums of terms so builders can express the legacy
/// engine's exact arithmetic (e.g. "block forward plus half an LM-head")
/// and the executor can price each term against *that node's own GPU*.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlopsTerm {
    pub flops: f64,
    pub scale: f64,
}

impl FlopsTerm {
    pub fn new(flops: f64) -> Self {
        Self { flops, scale: 1.0 }
    }
    pub fn scaled(flops: f64, scale: f64) -> Self {
        Self { flops, scale }
    }
}

/// The typed operations a schedule node can perform.
#[derive(Clone, Debug)]
pub enum Op {
    /// A host↔GPU DMA striped over memory nodes (fractions sum to 1).
    /// Becomes one flow per positive stripe; the node completes when the
    /// last stripe lands.
    Transfer {
        gpu: GpuId,
        stripes: Vec<(NodeId, f64)>,
        dir: Dir,
        bytes: f64,
    },
    /// A GPU kernel: Σ scaleᵢ·(flopsᵢ / gpu-effective-FLOPs) seconds,
    /// priced with the *owning GPU's* rating (a slow card lengthens its
    /// own lane only).
    Compute { gpu: GpuId, work: Vec<FlopsTerm> },
    /// A CPU phase timed by the calibrated memory model: one Adam update
    /// over `adam_elements` placed as `adam_layout`, plus pure streaming
    /// passes (the fp32→bf16 casts) summed in order.
    CpuStep {
        adam_elements: u64,
        adam_layout: OptLayout,
        streams: Vec<(f64, OptLayout)>,
    },
    /// Pure synchronization: completes the instant its deps complete, emits
    /// no fabric event and no trace span.
    Barrier,
}

/// Which memory-plan region a node's traffic is attributed to.
///
/// Touch annotations are *descriptive*: the executor prices ops from their
/// payloads alone and ignores touches entirely, so a builder that omits
/// them changes nothing about simulated time. They exist for the
/// tensor-access profiling pass ([`crate::mem::profile::profile_schedule`])
/// and the executor's per-region traffic ledger, which together close the
/// loop between the schedule and the memory subsystem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionTouch {
    /// The node's `Op::Transfer` bytes move to/from this region.
    Dma(RegionId),
    /// The node's `Op::CpuStep` Adam pass read-modify-writes this region
    /// (each listed region carries the node's full `adam_elements`).
    CpuRmw(RegionId),
    /// The `stream`-th entry of the node's `Op::CpuStep` streams
    /// reads/writes this region.
    CpuStream { region: RegionId, stream: usize },
    /// Liveness-only: the node consumes the region's contents without
    /// modeled traffic (e.g. the optimizer reading bf16 gradients, which
    /// the calibrated STEP model folds into the Adam pass). Extends the
    /// region's lifetime window but not its traffic counters.
    Keepalive(RegionId),
}

impl RegionTouch {
    /// The region this touch refers to.
    pub fn region(&self) -> RegionId {
        match self {
            RegionTouch::Dma(r)
            | RegionTouch::CpuRmw(r)
            | RegionTouch::Keepalive(r)
            | RegionTouch::CpuStream { region: r, .. } => *r,
        }
    }
}

/// A schedule node: the op, its dependency edges, and its reporting labels.
#[derive(Clone, Debug)]
pub struct OpNode {
    pub op: Op,
    /// All of these must complete before the node is issued.
    pub deps: Vec<OpId>,
    /// Trace span label, e.g. `"param-load b3"`.
    pub name: String,
    /// Trace lane, e.g. `"gpu0/h2d"`.
    pub lane: String,
    /// Index into [`Schedule::phases`].
    pub phase: usize,
    /// Marks a phase *boundary* node: the phase's boundary time is the max
    /// completion over its marked nodes (legacy FWD/BWD/STEP semantics).
    pub ends_phase: bool,
    /// Plan regions whose traffic/liveness this node represents (may be
    /// empty for unattributed ops; never affects executor timing).
    pub touches: Vec<RegionTouch>,
}

/// A whole iteration as a task DAG.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Phase names in declaration order (`PhaseReport` preserves it).
    pub phases: Vec<String>,
    pub nodes: Vec<OpNode>,
    /// Tokens processed by one execution of this schedule (all GPUs, all
    /// micro-batches).
    pub tokens: u64,
}

impl Schedule {
    pub fn new(tokens: u64) -> Self {
        Self {
            phases: Vec::new(),
            nodes: Vec::new(),
            tokens,
        }
    }

    /// Intern a phase name, returning its index.
    pub fn phase(&mut self, name: &str) -> usize {
        if let Some(i) = self.phases.iter().position(|p| p == name) {
            return i;
        }
        self.phases.push(name.to_string());
        self.phases.len() - 1
    }

    /// Append a node; its index is its dispatch priority.
    pub fn push(&mut self, node: OpNode) -> OpId {
        assert!(
            self.nodes.len() < u32::MAX as usize,
            "schedule node count overflows OpId"
        );
        self.nodes.push(node);
        OpId(self.nodes.len() as u32 - 1)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Structural validation: in-bounds edges, an acyclic graph, sane op
    /// payloads, and (given the topology) valid GPU / memory-node indices.
    /// Backed by the static verifier ([`crate::analysis::lint_schedule`]);
    /// returns the first `Error`-severity diagnostic, rendered. Warnings
    /// (dishonest annotations, isolated nodes, …) do not fail this path —
    /// use [`Schedule::validate_strict`] for that.
    pub fn validate(&self, topo: &SystemTopology) -> Result<(), String> {
        self.validated_adjacency(topo).map(|_| ())
    }

    /// [`Schedule::validate`] that also fails on `Warn`-severity
    /// diagnostics (annotation honesty, isolated nodes, empty phases,
    /// vacuous barriers). What CI's `lint --all --deny-warnings` holds
    /// every registered builder to.
    pub fn validate_strict(&self, topo: &SystemTopology) -> Result<(), String> {
        let diags = crate::analysis::lint_schedule(self, topo, None);
        match diags.first_at_least(crate::analysis::Severity::Warn) {
            Some(d) => Err(d.render()),
            None => Ok(()),
        }
    }

    /// FNV-1a digest over the schedule's full structural content: phases,
    /// tokens, and every node's op payload (discriminants, `to_bits`
    /// floats), edges, labels, phase markers, and touch annotations. Two
    /// schedules digest equal iff the executor (and the profiling pass)
    /// cannot tell them apart — the key contract the sweep's DAG memo and
    /// the memo-soundness property tests are built on.
    pub fn digest(&self) -> u64 {
        use crate::util::digest::Fnv64;
        let mut h = Fnv64::new();
        h.write_u64(self.phases.len() as u64);
        for p in &self.phases {
            h.write_str(p);
        }
        h.write_u64(self.tokens);
        h.write_u64(self.nodes.len() as u64);
        let mut layout = |h: &mut Fnv64, l: &crate::sim::memmodel::OptLayout| {
            h.write_u64(l.parts.len() as u64);
            for (n, f) in &l.parts {
                h.write_u64(n.0 as u64).write_f64(*f);
            }
            h.write_u64(match l.mode {
                crate::sim::memmodel::AccessMode::Interleaved => 0,
                crate::sim::memmodel::AccessMode::Partitioned => 1,
            });
        };
        for node in &self.nodes {
            match &node.op {
                Op::Transfer { gpu, stripes, dir, bytes } => {
                    h.write_u64(0).write_u64(gpu.0 as u64);
                    h.write_u64(stripes.len() as u64);
                    for (n, f) in stripes {
                        h.write_u64(n.0 as u64).write_f64(*f);
                    }
                    h.write_u64(match dir {
                        Dir::HostToGpu => 0,
                        Dir::GpuToHost => 1,
                    });
                    h.write_f64(*bytes);
                }
                Op::Compute { gpu, work } => {
                    h.write_u64(1).write_u64(gpu.0 as u64);
                    h.write_u64(work.len() as u64);
                    for t in work {
                        h.write_f64(t.flops).write_f64(t.scale);
                    }
                }
                Op::CpuStep { adam_elements, adam_layout, streams } => {
                    h.write_u64(2).write_u64(*adam_elements);
                    layout(&mut h, adam_layout);
                    h.write_u64(streams.len() as u64);
                    for (bytes, l) in streams {
                        h.write_f64(*bytes);
                        layout(&mut h, l);
                    }
                }
                Op::Barrier => {
                    h.write_u64(3);
                }
            }
            h.write_u64(node.deps.len() as u64);
            for d in &node.deps {
                h.write_u64(d.0 as u64);
            }
            h.write_str(&node.name).write_str(&node.lane);
            h.write_u64(node.phase as u64);
            h.write_u64(u64::from(node.ends_phase));
            h.write_u64(node.touches.len() as u64);
            for t in &node.touches {
                match t {
                    RegionTouch::Dma(r) => {
                        h.write_u64(0).write_u64(r.0 as u64);
                    }
                    RegionTouch::CpuRmw(r) => {
                        h.write_u64(1).write_u64(r.0 as u64);
                    }
                    RegionTouch::CpuStream { region, stream } => {
                        h.write_u64(2).write_u64(region.0 as u64).write_u64(*stream as u64);
                    }
                    RegionTouch::Keepalive(r) => {
                        h.write_u64(3).write_u64(r.0 as u64);
                    }
                }
            }
        }
        h.finish()
    }

    /// [`Schedule::validate`] that additionally hands back the dependency
    /// bookkeeping the lint pass had to build anyway — `(indegree,
    /// dependents)` per node — so the executor does not rebuild the
    /// O(V+E) adjacency.
    pub(crate) fn validated_adjacency(
        &self,
        topo: &SystemTopology,
    ) -> Result<(Vec<u32>, Vec<Vec<u32>>), String> {
        let (diags, adjacency) = crate::analysis::lint_schedule_adjacency(self, topo, None);
        match diags.first_error() {
            Some(d) => Err(d.render()),
            None => Ok(adjacency.expect("error-free lint always yields adjacency")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::dev_tiny;

    fn transfer(deps: Vec<OpId>, phase: usize) -> OpNode {
        OpNode {
            op: Op::Transfer {
                gpu: GpuId(0),
                stripes: vec![(NodeId(0), 1.0)],
                dir: Dir::HostToGpu,
                bytes: 1e6,
            },
            deps,
            name: "t".into(),
            lane: "gpu0/h2d".into(),
            phase,
            ends_phase: false,
            touches: vec![],
        }
    }

    #[test]
    fn phases_intern_stably() {
        let mut s = Schedule::new(0);
        assert_eq!(s.phase("fwd"), 0);
        assert_eq!(s.phase("bwd"), 1);
        assert_eq!(s.phase("fwd"), 0, "re-interning returns the same index");
        assert_eq!(s.phases, vec!["fwd".to_string(), "bwd".to_string()]);
    }

    #[test]
    fn valid_chain_passes() {
        let topo = dev_tiny();
        let mut s = Schedule::new(128);
        s.phase("fwd");
        let a = s.push(transfer(vec![], 0));
        let b = s.push(transfer(vec![a], 0));
        s.push(transfer(vec![a, b], 0));
        assert!(s.validate(&topo).is_ok());
    }

    #[test]
    fn cycle_is_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        // 0 → 1 → 0 (forward reference then back-edge)
        s.push(transfer(vec![OpId(1)], 0));
        s.push(transfer(vec![OpId(0)], 0));
        let err = s.validate(&topo).unwrap_err();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn self_dep_is_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        s.push(transfer(vec![OpId(0)], 0));
        assert!(s.validate(&topo).unwrap_err().contains("itself"));
    }

    #[test]
    fn out_of_range_dep_is_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        s.push(transfer(vec![OpId(7)], 0));
        assert!(s.validate(&topo).unwrap_err().contains("out-of-range"));
    }

    #[test]
    fn bad_stripes_and_phase_are_rejected() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        let mut n = transfer(vec![], 0);
        if let Op::Transfer { stripes, .. } = &mut n.op {
            stripes[0].1 = 0.5; // does not sum to 1
        }
        s.push(n);
        assert!(s.validate(&topo).unwrap_err().contains("stripe fractions"));

        let mut s2 = Schedule::new(0);
        s2.phase("fwd");
        let mut n2 = transfer(vec![], 0);
        n2.phase = 3; // never declared
        s2.push(n2);
        assert!(s2.validate(&topo).unwrap_err().contains("phase 3"));
    }

    #[test]
    fn unknown_gpu_is_rejected() {
        let topo = dev_tiny(); // 2 GPUs
        let mut s = Schedule::new(0);
        s.phase("fwd");
        let mut n = transfer(vec![], 0);
        if let Op::Transfer { gpu, .. } = &mut n.op {
            *gpu = GpuId(5);
        }
        s.push(n);
        assert!(s.validate(&topo).unwrap_err().contains("gpu 5"));
    }

    #[test]
    fn empty_schedule_is_rejected() {
        let topo = dev_tiny();
        let s = Schedule::new(0);
        assert!(s.validate(&topo).is_err());
    }

    #[test]
    fn touch_kind_must_match_op_kind() {
        use crate::mem::RegionId;
        let topo = dev_tiny();
        // Dma touch on a Transfer: fine.
        let mut s = Schedule::new(0);
        s.phase("fwd");
        let mut n = transfer(vec![], 0);
        n.touches = vec![RegionTouch::Dma(RegionId(0)), RegionTouch::Keepalive(RegionId(1))];
        s.push(n);
        assert!(s.validate(&topo).is_ok());
        // CpuRmw touch on a Transfer: rejected.
        let mut s2 = Schedule::new(0);
        s2.phase("fwd");
        let mut n2 = transfer(vec![], 0);
        n2.touches = vec![RegionTouch::CpuRmw(RegionId(0))];
        s2.push(n2);
        assert!(s2.validate(&topo).unwrap_err().contains("CpuRmw"));
        // CpuStream index out of range: rejected.
        let mut s3 = Schedule::new(0);
        s3.phase("step");
        s3.push(OpNode {
            op: Op::CpuStep {
                adam_elements: 10,
                adam_layout: OptLayout::dram_only(),
                streams: vec![(1e6, OptLayout::dram_only())],
            },
            deps: vec![],
            name: "step".into(),
            lane: "cpu/step".into(),
            phase: 0,
            ends_phase: true,
            touches: vec![RegionTouch::CpuStream {
                region: RegionId(0),
                stream: 1,
            }],
        });
        assert!(s3.validate(&topo).unwrap_err().contains("stream touch"));
    }

    #[test]
    fn cycle_error_names_the_stuck_nodes() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("fwd");
        // 2 is a clean root; 0 ↔ 1 form the cycle. The error must say
        // which nodes are stuck, not just that a cycle exists.
        s.push(transfer(vec![OpId(1)], 0));
        s.push(transfer(vec![OpId(0)], 0));
        s.push(transfer(vec![], 0));
        let err = s.validate(&topo).unwrap_err();
        assert!(err.contains("cycle"), "{err}");
        assert!(err.contains("node 0"), "stuck nodes must be named: {err}");
        assert!(err.contains("node 1"), "stuck nodes must be named: {err}");
    }

    #[test]
    fn validate_strict_rejects_dishonest_transfer() {
        // A transfer that moves bytes but carries no Dma touch passes
        // plain validation (annotations are descriptive) but is exactly
        // the dishonesty the strict gate exists to catch.
        let topo = dev_tiny();
        let mut s = Schedule::new(128);
        s.phase("fwd");
        let a = s.push(transfer(vec![], 0));
        s.push(transfer(vec![a], 0));
        assert!(s.validate(&topo).is_ok());
        let err = s.validate_strict(&topo).unwrap_err();
        assert!(err.contains("P009"), "{err}");
    }

    #[test]
    fn validate_strict_accepts_honest_annotations() {
        use crate::mem::RegionId;
        let topo = dev_tiny();
        let mut s = Schedule::new(128);
        s.phase("fwd");
        let mut n1 = transfer(vec![], 0);
        n1.touches = vec![RegionTouch::Dma(RegionId(0))];
        let a = s.push(n1);
        let mut n2 = transfer(vec![a], 0);
        n2.touches = vec![RegionTouch::Dma(RegionId(1))];
        s.push(n2);
        assert!(s.validate_strict(&topo).is_ok());
    }

    #[test]
    fn digest_separates_structural_differences() {
        let mut base = Schedule::new(128);
        base.phase("fwd");
        let a = base.push(transfer(vec![], 0));
        base.push(transfer(vec![a], 0));
        let d0 = base.digest();
        assert_eq!(base.digest(), d0, "digest is a pure function");

        // Same shape, different payload byte count.
        let mut b = Schedule::new(128);
        b.phase("fwd");
        let a = b.push(transfer(vec![], 0));
        let mut n = transfer(vec![a], 0);
        if let Op::Transfer { bytes, .. } = &mut n.op {
            *bytes += 1.0;
        }
        b.push(n);
        assert_ne!(b.digest(), d0, "payload bytes must be digested");

        // Same nodes, different edge set.
        let mut c = Schedule::new(128);
        c.phase("fwd");
        c.push(transfer(vec![], 0));
        c.push(transfer(vec![], 0));
        assert_ne!(c.digest(), d0, "dependency edges must be digested");

        // Same graph, different token count.
        let mut t = Schedule::new(129);
        t.phase("fwd");
        let a = t.push(transfer(vec![], 0));
        t.push(transfer(vec![a], 0));
        assert_ne!(t.digest(), d0, "tokens must be digested");

        // Touch annotations distinguish too (the profiling pass sees them).
        use crate::mem::RegionId;
        let mut u = Schedule::new(128);
        u.phase("fwd");
        let a = u.push(transfer(vec![], 0));
        let mut n = transfer(vec![a], 0);
        n.touches = vec![RegionTouch::Dma(RegionId(0))];
        u.push(n);
        assert_ne!(u.digest(), d0, "touches must be digested");
    }

    #[test]
    fn touch_region_accessor() {
        use crate::mem::RegionId;
        assert_eq!(RegionTouch::Dma(RegionId(3)).region(), RegionId(3));
        assert_eq!(RegionTouch::CpuRmw(RegionId(1)).region(), RegionId(1));
        assert_eq!(RegionTouch::Keepalive(RegionId(2)).region(), RegionId(2));
        assert_eq!(
            RegionTouch::CpuStream {
                region: RegionId(4),
                stream: 0
            }
            .region(),
            RegionId(4)
        );
    }
}
