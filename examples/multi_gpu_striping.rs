//! Multi-AIC striping demo (§IV-B / Fig. 8b): watch the bandwidth collapse
//! when two GPUs hammer one AIC, then watch striping across two AICs
//! recover the aggregate — the paper's Fig. 6b → Fig. 10 story in one run.
//!
//! ```bash
//! cargo run --release --example multi_gpu_striping
//! ```

use cxlfine::sim::{Dir, Fabric};
use cxlfine::topology::presets::{config_a, config_b};
use cxlfine::topology::{GpuId, NodeId};
use cxlfine::util::units::{fmt_rate, GIB};

fn aggregate(fab: &mut Fabric, total_bytes: f64) -> f64 {
    fab.sim.run_to_idle();
    total_bytes / fab.now()
}

fn main() {
    let bytes = 4.0 * GIB as f64;

    println!("=== scene 1: single GPU, single AIC (Fig. 6a) ===");
    let topo_a = config_a();
    let cxl = topo_a.cxl_nodes()[0];
    for (label, node) in [("local DRAM", NodeId(0)), ("CXL AIC", cxl)] {
        let mut fab = Fabric::new(&topo_a);
        fab.transfer(GpuId(0), node, Dir::HostToGpu, bytes, 0);
        let rate = aggregate(&mut fab, bytes);
        println!("  1 GPU pulling from {label:<10}: {}", fmt_rate(rate));
    }
    println!("  → parity: page-locked DMA makes the copy interface-bound.\n");

    println!("=== scene 2: two GPUs share one AIC (Fig. 6b) ===");
    for (label, node) in [("local DRAM", NodeId(0)), ("CXL AIC", cxl)] {
        let mut fab = Fabric::new(&topo_a);
        fab.transfer(GpuId(0), node, Dir::HostToGpu, bytes, 0);
        fab.transfer(GpuId(1), node, Dir::HostToGpu, bytes, 1);
        let rate = aggregate(&mut fab, 2.0 * bytes);
        println!("  2 GPUs pulling from {label:<10}: {} aggregate", fmt_rate(rate));
    }
    println!("  → the shared AIC link collapses to ~25 GiB/s — less than ONE uncontended stream.\n");

    println!("=== scene 3: two GPUs, two AICs (Config B) ===");
    let topo_b = config_b();
    let cxl_nodes = topo_b.cxl_nodes();

    // naive: GPU i → AIC i (no contention, but no pooling either)
    let mut fab = Fabric::new(&topo_b);
    fab.transfer(GpuId(0), cxl_nodes[0], Dir::HostToGpu, bytes, 0);
    fab.transfer(GpuId(1), cxl_nodes[1], Dir::HostToGpu, bytes, 1);
    let affinity = aggregate(&mut fab, 2.0 * bytes);

    // both GPUs on one AIC (what naive interleave does under load skew)
    let mut fab = Fabric::new(&topo_b);
    fab.transfer(GpuId(0), cxl_nodes[0], Dir::HostToGpu, bytes, 0);
    fab.transfer(GpuId(1), cxl_nodes[0], Dir::HostToGpu, bytes, 1);
    let skewed = aggregate(&mut fab, 2.0 * bytes);

    // striped: every transfer split across both AICs (§IV-B)
    let stripes = [(cxl_nodes[0], 0.5), (cxl_nodes[1], 0.5)];
    let mut fab = Fabric::new(&topo_b);
    fab.transfer_striped(GpuId(0), &stripes, Dir::HostToGpu, bytes, 0);
    fab.transfer_striped(GpuId(1), &stripes, Dir::HostToGpu, bytes, 1);
    let striped = aggregate(&mut fab, 2.0 * bytes);

    println!("  both GPUs on one AIC:        {} aggregate", fmt_rate(skewed));
    println!("  per-GPU AIC affinity:        {} aggregate", fmt_rate(affinity));
    println!("  striped across both AICs:    {} aggregate", fmt_rate(striped));
    println!("\n  → striping pools both links and keeps every card out of the");
    println!("    oversubscribed regime (Fig. 8b).");

    // scene 4: one GPU, two AICs. On Gen5 hardware the GPU's own ×16 link
    // already matches one AIC, so striping is rate-neutral for a single
    // GPU — its value is contention avoidance, not single-stream speed.
    let mut fab = Fabric::new(&topo_b);
    fab.transfer_striped(GpuId(0), &stripes, Dir::HostToGpu, bytes, 0);
    let pooled = aggregate(&mut fab, bytes);
    let mut fab = Fabric::new(&topo_b);
    fab.transfer(GpuId(0), cxl_nodes[0], Dir::HostToGpu, bytes, 0);
    let single = aggregate(&mut fab, bytes);
    println!("\n=== scene 4: one GPU, striped over two AICs ===");
    println!("  single AIC: {}   striped: {}", fmt_rate(single), fmt_rate(pooled));
    println!("  → rate-neutral for one GPU (its own PCIe link is the cap);");
    println!("    the win appears exactly when multiple GPUs contend (scene 3).");
}
