//! Schedule lints: the structural checks `Schedule::validate` has always
//! enforced (now emitted as diagnostics instead of early-returned strings),
//! plus annotation-honesty warnings and plan-context checks the runtime
//! executor could previously only catch after the fact.
//!
//! Emission order is the legacy `validate` order — per node: phase, deps,
//! op payload, touches — with the cycle check last, so the first `Error`
//! in the returned [`Diagnostics`] is exactly the violation legacy callers
//! used to get back as a bare string.

use super::diag::{Anchor, Diagnostics, Severity};
use crate::mem::{Lifetime, RegionId};
use crate::offload::plan::MemoryPlan;
use crate::offload::schedule::{Op, OpNode, RegionTouch, Schedule};
use crate::topology::SystemTopology;

/// What the linter knows about one committed plan region.
#[derive(Clone, Debug)]
pub struct RegionInfo {
    pub id: RegionId,
    pub name: String,
    /// Liveness window the region was committed under (`None` = whole run).
    pub lifetime: Option<Lifetime>,
}

/// Plan-side context for schedule linting: which regions exist and the
/// lifetime windows they were committed under. Without it the
/// region-resolution (P007), lifetime-window (P008), and untouched-region
/// (P018) checks are skipped — `Schedule::validate` runs context-free
/// because schedules are built against a plan that may not exist yet;
/// `MemoryPlan` paths and the CLI lint against the real plan.
#[derive(Clone, Debug, Default)]
pub struct ScheduleLintContext {
    pub regions: Vec<RegionInfo>,
}

impl ScheduleLintContext {
    pub fn from_plan(plan: &MemoryPlan<'_>) -> Self {
        Self {
            regions: plan
                .alloc
                .regions()
                .map(|r| RegionInfo {
                    id: r.id,
                    name: r.name.clone(),
                    lifetime: r.lifetime,
                })
                .collect(),
        }
    }

    fn find(&self, id: RegionId) -> Option<(usize, &RegionInfo)> {
        self.regions.iter().enumerate().find(|(_, r)| r.id == id)
    }
}

/// Lint a schedule against a topology and (optionally) the memory plan it
/// annotates. See DESIGN.md §12 for the code catalog.
pub fn lint_schedule(
    sched: &Schedule,
    topo: &SystemTopology,
    ctx: Option<&ScheduleLintContext>,
) -> Diagnostics {
    lint_schedule_adjacency(sched, topo, ctx).0
}

fn node_anchor(i: usize, node: &OpNode) -> Anchor {
    Anchor::Node {
        index: i,
        name: node.name.clone(),
    }
}

/// [`lint_schedule`] that additionally hands back the dependency
/// bookkeeping it had to build anyway — `(indegree, dependents)` per node
/// — when the schedule is structurally clean, so the executor does not
/// rebuild the O(V+E) adjacency. `None` whenever any `Error` was emitted.
pub(crate) fn lint_schedule_adjacency(
    sched: &Schedule,
    topo: &SystemTopology,
    ctx: Option<&ScheduleLintContext>,
) -> (Diagnostics, Option<(Vec<u32>, Vec<Vec<u32>>)>) {
    let mut ds = Diagnostics::new();
    if sched.nodes.is_empty() {
        ds.push(
            "P001",
            Severity::Error,
            Anchor::General,
            "schedule has no nodes",
        );
        return (ds, None);
    }
    let n = sched.nodes.len();

    // Dependency bookkeeping up front (shared with the executor): the
    // executor-facing indegree counts every listed edge; the Kahn scratch
    // counts only well-formed edges so a bad index cannot masquerade as a
    // cycle. On a clean schedule the two are identical.
    let mut indeg: Vec<u32> = vec![0; n];
    let mut valid_indeg: Vec<u32> = vec![0; n];
    let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (i, node) in sched.nodes.iter().enumerate() {
        indeg[i] = node.deps.len() as u32;
        for d in &node.deps {
            if (d.0 as usize) < n && d.0 as usize != i {
                valid_indeg[i] += 1;
                dependents[d.0 as usize].push(i as u32);
            }
        }
    }

    let mut touched: Vec<bool> = vec![false; ctx.map_or(0, |c| c.regions.len())];
    for (i, node) in sched.nodes.iter().enumerate() {
        if node.phase >= sched.phases.len() {
            ds.push(
                "P002",
                Severity::Error,
                node_anchor(i, node),
                format!(
                    "references phase {} but only {} are declared",
                    node.phase,
                    sched.phases.len()
                ),
            );
        }
        let mut seen_deps: Vec<u32> = Vec::new();
        for d in &node.deps {
            if d.0 as usize >= n {
                ds.push(
                    "P003",
                    Severity::Error,
                    node_anchor(i, node),
                    format!("depends on out-of-range node {}", d.0),
                );
            } else if d.0 as usize == i {
                ds.push(
                    "P003",
                    Severity::Error,
                    node_anchor(i, node),
                    "depends on itself",
                );
            } else if d.0 as usize > i {
                ds.push(
                    "P014",
                    Severity::Warn,
                    node_anchor(i, node),
                    format!(
                        "depends on later node {} — dispatch priority (index order) is inverted \
                         across this edge",
                        d.0
                    ),
                );
            }
            if seen_deps.contains(&d.0) {
                ds.push(
                    "P015",
                    Severity::Warn,
                    node_anchor(i, node),
                    format!("lists dependency on node {} more than once", d.0),
                );
            } else {
                seen_deps.push(d.0);
            }
        }
        lint_op_payload(&mut ds, i, node, topo);
        for t in &node.touches {
            lint_touch_kind(&mut ds, i, node, t);
            if let Some(c) = ctx {
                match c.find(t.region()) {
                    None => ds.push(
                        "P007",
                        Severity::Error,
                        node_anchor(i, node),
                        format!(
                            "touches region id {} which is not in the memory plan \
                             ({} regions committed)",
                            t.region().0,
                            c.regions.len()
                        ),
                    ),
                    Some((k, info)) => {
                        touched[k] = true;
                        if let Some(lt) = &info.lifetime {
                            if !lt.contains(node.phase as u32) {
                                ds.push(
                                    "P008",
                                    Severity::Error,
                                    node_anchor(i, node),
                                    format!(
                                        "touches region '{}' at phase {} outside its committed \
                                         lifetime {lt}",
                                        info.name, node.phase
                                    ),
                                );
                            }
                        }
                    }
                }
            }
        }
        lint_honesty(&mut ds, i, node);
        let gated = !dependents[i].is_empty();
        if matches!(node.op, Op::Barrier) {
            if node.deps.is_empty() {
                ds.push(
                    "P016",
                    Severity::Warn,
                    node_anchor(i, node),
                    "barrier waits on nothing (no dependencies)",
                );
            } else if !gated {
                ds.push(
                    "P016",
                    Severity::Warn,
                    node_anchor(i, node),
                    "barrier gates nothing (no dependents)",
                );
            }
        } else if n > 1 && node.deps.is_empty() && !gated {
            ds.push(
                "P012",
                Severity::Warn,
                node_anchor(i, node),
                "is isolated: no dependencies and nothing depends on it",
            );
        } else if !gated && !node.ends_phase {
            ds.push(
                "P017",
                Severity::Info,
                node_anchor(i, node),
                "terminal node does not mark a phase boundary (ends_phase = false)",
            );
        }
    }

    // Phases no node occupies.
    let mut occupancy = vec![0usize; sched.phases.len()];
    for node in &sched.nodes {
        if node.phase < occupancy.len() {
            occupancy[node.phase] += 1;
        }
    }
    for (p, &count) in occupancy.iter().enumerate() {
        if count == 0 {
            ds.push(
                "P013",
                Severity::Warn,
                Anchor::Phase { index: p },
                format!("phase '{}' has no nodes", sched.phases[p]),
            );
        }
    }

    // Committed regions the schedule never mentions (benign for ablations
    // like no-act-offload, hence Info — but a new builder forgetting its
    // annotations entirely shows up here).
    if let Some(c) = ctx {
        for (k, info) in c.regions.iter().enumerate() {
            if !touched[k] {
                ds.push(
                    "P018",
                    Severity::Info,
                    Anchor::Region {
                        name: info.name.clone(),
                    },
                    "committed but never touched by the schedule \
                     (no traffic or liveness annotations)",
                );
            }
        }
    }

    // Kahn's algorithm over the well-formed edges: every node must drain,
    // otherwise the stuck set sits on or downstream of a cycle.
    let mut scratch = valid_indeg;
    let mut queue: Vec<u32> = (0..n as u32).filter(|&i| scratch[i as usize] == 0).collect();
    let mut seen = 0usize;
    while let Some(i) = queue.pop() {
        seen += 1;
        for &j in &dependents[i as usize] {
            scratch[j as usize] -= 1;
            if scratch[j as usize] == 0 {
                queue.push(j);
            }
        }
    }
    if seen != n {
        let stuck: Vec<usize> = (0..n).filter(|&i| scratch[i] > 0).collect();
        let mut names = stuck
            .iter()
            .take(6)
            .map(|&i| format!("node {i} ({})", sched.nodes[i].name))
            .collect::<Vec<_>>()
            .join(", ");
        if stuck.len() > 6 {
            names.push_str(&format!(", … {} more", stuck.len() - 6));
        }
        let first = stuck[0];
        ds.push(
            "P004",
            Severity::Error,
            node_anchor(first, &sched.nodes[first]),
            format!("schedule graph has a cycle ({seen} of {n} nodes reachable; stuck: {names})"),
        );
    }

    let adjacency = if ds.has_errors() {
        None
    } else {
        Some((indeg, dependents))
    };
    (ds, adjacency)
}

/// P005: op payload sanity (GPU / memory-node indices, stripe fractions,
/// finite byte and FLOPs quantities). Messages match the legacy
/// `validate` wording.
fn lint_op_payload(ds: &mut Diagnostics, i: usize, node: &OpNode, topo: &SystemTopology) {
    match &node.op {
        Op::Transfer {
            gpu,
            stripes,
            bytes,
            ..
        } => {
            if gpu.0 >= topo.gpus.len() {
                ds.push(
                    "P005",
                    Severity::Error,
                    node_anchor(i, node),
                    format!("targets gpu {} but topology has {}", gpu.0, topo.gpus.len()),
                );
            }
            if stripes.is_empty() {
                ds.push(
                    "P005",
                    Severity::Error,
                    node_anchor(i, node),
                    "has no stripes",
                );
            } else {
                let total: f64 = stripes.iter().map(|(_, f)| *f).sum();
                if (total - 1.0).abs() > 1e-6 {
                    ds.push(
                        "P005",
                        Severity::Error,
                        node_anchor(i, node),
                        format!("stripe fractions sum to {total}"),
                    );
                }
                for (mem, _) in stripes {
                    if mem.0 >= topo.mem_nodes.len() {
                        ds.push(
                            "P005",
                            Severity::Error,
                            node_anchor(i, node),
                            format!("stripes onto unknown memory node {}", mem.0),
                        );
                    }
                }
            }
            if !bytes.is_finite() || *bytes < 0.0 {
                ds.push(
                    "P005",
                    Severity::Error,
                    node_anchor(i, node),
                    format!("has bad byte count {bytes}"),
                );
            }
        }
        Op::Compute { gpu, work } => {
            if gpu.0 >= topo.gpus.len() {
                ds.push(
                    "P005",
                    Severity::Error,
                    node_anchor(i, node),
                    format!(
                        "computes on gpu {} but topology has {}",
                        gpu.0,
                        topo.gpus.len()
                    ),
                );
            }
            if work.is_empty() {
                ds.push(
                    "P005",
                    Severity::Error,
                    node_anchor(i, node),
                    "has no FLOPs terms",
                );
            }
            for t in work {
                if !t.flops.is_finite() || t.flops < 0.0 || !t.scale.is_finite() {
                    ds.push(
                        "P005",
                        Severity::Error,
                        node_anchor(i, node),
                        format!("has bad FLOPs term {t:?}"),
                    );
                }
            }
        }
        Op::CpuStep { streams, .. } => {
            for (bytes, _) in streams {
                if !bytes.is_finite() || *bytes < 0.0 {
                    ds.push(
                        "P005",
                        Severity::Error,
                        node_anchor(i, node),
                        format!("has bad stream byte count {bytes}"),
                    );
                }
            }
        }
        Op::Barrier => {}
    }
}

/// P006: touch kind must match the op kind (a `Dma` touch describes
/// `Transfer` bytes, `CpuRmw`/`CpuStream` describe `CpuStep` passes).
fn lint_touch_kind(ds: &mut Diagnostics, i: usize, node: &OpNode, t: &RegionTouch) {
    match t {
        RegionTouch::Dma(_) => {
            if !matches!(node.op, Op::Transfer { .. }) {
                ds.push(
                    "P006",
                    Severity::Error,
                    node_anchor(i, node),
                    "has a Dma touch on a non-Transfer op",
                );
            }
        }
        RegionTouch::CpuRmw(_) => {
            if !matches!(node.op, Op::CpuStep { .. }) {
                ds.push(
                    "P006",
                    Severity::Error,
                    node_anchor(i, node),
                    "has a CpuRmw touch on a non-CpuStep op",
                );
            }
        }
        RegionTouch::CpuStream { stream, .. } => match &node.op {
            Op::CpuStep { streams, .. } => {
                if *stream >= streams.len() {
                    ds.push(
                        "P006",
                        Severity::Error,
                        node_anchor(i, node),
                        format!("stream touch {} out of range ({} streams)", stream, streams.len()),
                    );
                }
            }
            _ => {
                ds.push(
                    "P006",
                    Severity::Error,
                    node_anchor(i, node),
                    "has a CpuStream touch on a non-CpuStep op",
                );
            }
        },
        RegionTouch::Keepalive(_) => {}
    }
}

/// P009–P011: annotation honesty — an op that moves bytes must say which
/// region they belong to, or profiling undercounts and every downstream
/// lifetime / placement / admission decision sees a rosier schedule than
/// the executor will run. This is the dishonesty the runtime ledger test
/// (`executor_ledger_validates_profiles`) can only catch after execution.
fn lint_honesty(ds: &mut Diagnostics, i: usize, node: &OpNode) {
    match &node.op {
        Op::Transfer { bytes, .. } => {
            if *bytes > 0.0
                && !node
                    .touches
                    .iter()
                    .any(|t| matches!(t, RegionTouch::Dma(_)))
            {
                ds.push(
                    "P009",
                    Severity::Warn,
                    node_anchor(i, node),
                    format!(
                        "moves {bytes:.0} bytes with no Dma touch — traffic invisible to \
                         profiling"
                    ),
                );
            }
        }
        Op::CpuStep {
            adam_elements,
            streams,
            ..
        } => {
            if *adam_elements > 0
                && !node
                    .touches
                    .iter()
                    .any(|t| matches!(t, RegionTouch::CpuRmw(_)))
            {
                ds.push(
                    "P010",
                    Severity::Warn,
                    node_anchor(i, node),
                    format!(
                        "runs Adam over {adam_elements} elements with no CpuRmw touch — \
                         optimizer traffic invisible to profiling"
                    ),
                );
            }
            for (k, (bytes, _)) in streams.iter().enumerate() {
                if *bytes > 0.0
                    && !node
                        .touches
                        .iter()
                        .any(|t| matches!(t, RegionTouch::CpuStream { stream, .. } if *stream == k))
                {
                    ds.push(
                        "P011",
                        Severity::Warn,
                        node_anchor(i, node),
                        format!(
                            "stream {k} moves {bytes:.0} bytes with no CpuStream touch — \
                             cast traffic invisible to profiling"
                        ),
                    );
                }
            }
        }
        _ => {}
    }
}
