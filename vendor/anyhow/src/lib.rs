//! Minimal, API-compatible subset of the `anyhow` crate for fully offline
//! builds (the container has no crates.io access, so the repo vendors the
//! small slice of the API the codebase actually uses).
//!
//! Provided: [`Error`] (context chain, `{}` / `{:#}` formatting like real
//! anyhow), [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait for `Result`.

use std::fmt;

/// A dynamic error with an outermost-first context chain.
pub struct Error {
    /// `chain[0]` is the outermost message (most recently attached context);
    /// the last element is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like real anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, exactly
// like real anyhow — that's what makes the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outer_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "missing file");
    }

    #[test]
    fn context_trait_on_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Err(anyhow!("always fails: {}", x))
        }
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed (got 0)");
        assert_eq!(f(2).unwrap_err().to_string(), "always fails: 2");
    }
}
