"""L1 flash-attention kernel vs the pure-jnp oracle.

The hypothesis sweep is THE correctness signal for the kernel: shapes,
dtypes and block sizes are all generated, and every case must match the
materialized reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref

jax.config.update("jax_platform_name", "cpu")


def rand_qkv(key, bh, seq, d, dtype):
    ks = jax.random.split(key, 3)
    return [jax.random.normal(k, (bh, seq, d), dtype) for k in ks]


@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(1, 6),
    seq_pow=st.integers(3, 7),  # 8..128
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_over_shapes(bh, seq_pow, d, causal, seed):
    seq = 2**seq_pow
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), bh, seq, d, jnp.float32)
    out = attention.flash_attention(q, k, v, causal)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    block_q=st.sampled_from([8, 16, 32, 128]),
    block_k=st.sampled_from([8, 16, 32, 128]),
)
def test_block_size_invariance(block_q, block_k):
    # tiling must never change the numerics
    q, k, v = rand_qkv(jax.random.PRNGKey(7), 2, 64, 16, jnp.float32)
    out = attention.flash_attention(q, k, v, True, block_q, block_k)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_support(dtype):
    q, k, v = rand_qkv(jax.random.PRNGKey(3), 2, 32, 16, dtype)
    out = attention.flash_attention(q, k, v)
    want = ref.attention(q, k, v)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_causal_mask_blocks_future():
    # with causal=True, output at position t must not depend on k/v at >t
    q, k, v = rand_qkv(jax.random.PRNGKey(5), 1, 16, 8, jnp.float32)
    out1 = attention.flash_attention(q, k, v, True)
    k2 = k.at[:, 10:, :].set(99.0)
    v2 = v.at[:, 10:, :].set(-99.0)
    out2 = attention.flash_attention(q, k2, v2, True)
    np.testing.assert_allclose(out1[:, :10], out2[:, :10], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, 10:], out2[:, 10:])


def test_gradients_match_reference():
    q, k, v = rand_qkv(jax.random.PRNGKey(11), 2, 32, 16, jnp.float32)

    def f_kernel(q, k, v):
        return (attention.flash_attention(q, k, v) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention(q, k, v) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


def test_softmax_rows_sum_to_one_property():
    # attention output of constant V must be exactly that constant
    bh, seq, d = 2, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(1), (bh, seq, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (bh, seq, d))
    v = jnp.full((bh, seq, d), 3.25, jnp.float32)
    out = attention.flash_attention(q, k, v)
    np.testing.assert_allclose(out, np.full((bh, seq, d), 3.25), rtol=1e-5)


def test_vmem_footprint_is_sub_quadratic():
    # §8 structural target: per-step VMEM ≪ naive C² scores
    for seq in [1024, 4096, 16384]:
        d = 64
        used = attention.vmem_floats_per_step(seq, d)
        naive = seq * seq
        assert used < naive / 8, f"seq={seq}: {used} vs naive {naive}"


def test_jit_and_lowering_compatible():
    # the kernel must lower inside jit (what aot.py relies on)
    q, k, v = rand_qkv(jax.random.PRNGKey(13), 1, 32, 8, jnp.float32)
    out = jax.jit(lambda q, k, v: attention.flash_attention(q, k, v))(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
