//! Fleet-simulator scale bench: jobs/sec and sim-events/sec at 10-, 100-
//! and 1000-job traces for every registered admission policy, on the
//! §V-B-shaped host (config-a, 128 GiB DRAM).
//!
//! Gates (enforced in CI via `--smoke`):
//! * `placement-aware` ≥ `fifo` on aggregate tokens/sec at the pinned
//!   100-job mixed-context trace, and strictly fewer rejected jobs (the
//!   XL jobs in the static/lifetime gap are the difference).
//! * bit-identical result digests across reruns (the determinism
//!   contract at bench scale).
//!
//! Results land in `bench_out/fleet_scale/` and in `BENCH_fleet.json`
//! (override: `CXLFINE_BENCH_FLEET_OUT`), which the CI bench-smoke job
//! uploads on every push so the fleet-throughput trajectory is recorded
//! alongside the DES, schedule and capacity ones.

use std::time::Instant;

use cxlfine::fleet::{mixed_trace_with_xl, scheduler, simulate_fleet};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("fleet_scale");
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let threads = cxlfine::util::threadpool::default_threads();

    // Every scale carries 8 XL jobs (statically infeasible, lifetime
    // feasible) except the 10-job smoke point, which stays pure mixed.
    let scales: Vec<(usize, usize)> = if smoke {
        vec![(10, 0), (92, 8)]
    } else {
        vec![(10, 0), (92, 8), (992, 8)]
    };

    let mut json_scales = Vec::new();
    for (n_mixed, n_xl) in scales {
        let n_jobs = n_mixed + n_xl;
        let trace = mixed_trace_with_xl(&topo, 1007, n_mixed, n_xl);
        assert_eq!(
            trace.jobs.len(),
            n_jobs,
            "the XL static/lifetime gap cell must exist at 128 GiB DRAM"
        );
        let mut t = Table::new(&[
            "policy",
            "wall",
            "jobs/s",
            "events/s",
            "agg tok/s",
            "completed",
            "rejected",
        ])
        .left(0);
        let mut raws = Vec::new();
        let mut by_policy = Vec::new();
        for policy in scheduler::registry() {
            let t0 = Instant::now();
            let res = simulate_fleet(&topo, &trace, &policy, threads);
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            t.row(trow![
                policy.name(),
                format!("{wall:.2}s"),
                format!("{:.0}", n_jobs as f64 / wall),
                format!("{:.0}", res.n_events as f64 / wall),
                format!("{:.0}", res.aggregate_tokens_per_sec()),
                res.completed(),
                res.rejected()
            ]);
            let mut cell = JsonObj::new();
            cell.set("policy", policy.name());
            cell.set("wall_s", wall);
            cell.set("jobs_per_sec", n_jobs as f64 / wall);
            cell.set("events_per_sec", res.n_events as f64 / wall);
            cell.set("aggregate_tokens_per_sec", res.aggregate_tokens_per_sec());
            cell.set("completed", res.completed());
            cell.set("rejected", res.rejected());
            cell.set("digest", format!("{:016x}", res.digest()));
            raws.push(Json::Obj(cell));
            by_policy.push((policy.name().to_string(), res));
        }
        // The admission gate at the pinned 100-job mixed trace.
        if n_xl > 0 {
            let get = |name: &str| {
                by_policy
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, r)| r)
                    .expect("registered policy ran")
            };
            let (fifo, pa) = (get("fifo"), get("placement-aware"));
            assert!(
                pa.rejected() < fifo.rejected(),
                "{n_jobs} jobs: placement-aware must strictly beat fifo on rejections \
                 ({} vs {})",
                pa.rejected(),
                fifo.rejected()
            );
            if n_jobs <= 100 {
                assert!(
                    pa.aggregate_tokens_per_sec() + 1e-9 >= fifo.aggregate_tokens_per_sec(),
                    "100-job trace: placement-aware lost aggregate throughput: {:.1} vs {:.1}",
                    pa.aggregate_tokens_per_sec(),
                    fifo.aggregate_tokens_per_sec()
                );
            }
        }
        // Determinism at the smallest scale: a rerun is bit-identical.
        if n_jobs <= 10 {
            let policy = scheduler::by_name("fifo").unwrap();
            let a = simulate_fleet(&topo, &trace, &policy, 1);
            let b = simulate_fleet(&topo, &trace, &policy, threads);
            assert_eq!(a.digest(), b.digest(), "rerun must be bit-identical");
        }
        println!("{n_jobs}-job trace on {} ({} XL jobs)", topo.name, n_xl);
        report.section(&format!("jobs_{n_jobs}"), t, Json::Arr(raws.clone()));
        json_scales.push(Json::Obj({
            let mut o = JsonObj::new();
            o.set("n_jobs", n_jobs);
            o.set("n_xl", n_xl);
            o.set("trace_digest", format!("{:016x}", trace.digest()));
            o.set("policies", Json::Arr(raws));
            o
        }));
    }

    let mut root = JsonObj::new();
    root.set("bench", "fleet_scale");
    root.set("smoke", smoke);
    root.set("scales", Json::Arr(json_scales));
    let out =
        std::env::var("CXLFINE_BENCH_FLEET_OUT").unwrap_or_else(|_| "BENCH_fleet.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[fleet_scale] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
