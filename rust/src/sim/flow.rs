//! Flow-level discrete-event simulator with max-min fair bandwidth sharing.
//!
//! Transfers are modeled as *fluid flows* over a set of resources (PCIe
//! links, DRAM controllers). At every event boundary the simulator solves
//! the max-min fair allocation ("progressive filling"): repeatedly find the
//! bottleneck resource, fix the fair share of all its unassigned flows, and
//! subtract. Resource capacity may depend on the number of concurrent flows
//! (the CXL-AIC contention collapse of Fig. 6b).
//!
//! The workflow engine drives the simulator interactively: it starts flows
//! and timers, then consumes completion events one at a time, starting
//! dependent work as each finishes — exactly how the real coordinator
//! overlaps transfers with compute.

use std::collections::HashMap;

/// Seconds since simulation start.
pub type SimTime = f64;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ResourceId(pub usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FlowId(pub u64);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TimerId(pub u64);

/// How a resource's usable capacity responds to load.
#[derive(Clone, Debug)]
pub enum CapacityModel {
    /// Fixed capacity regardless of load (DRAM controllers, GPU links).
    Fixed(f64),
    /// A CXL AIC link (Fig. 6b): delivers `single` as long as the *offered
    /// load* (what its flows would pull if this link were infinite) stays
    /// within `single`; once oversubscribed by ≥2 independent DMA streams,
    /// competing request queues defeat the device-side scheduling and the
    /// aggregate collapses to `contended`. This load-dependence is exactly
    /// why multi-AIC striping works (§IV-B): striped transfers offer each
    /// card ≤ its capacity, so no card ever enters the collapsed regime.
    Contended { single: f64, contended: f64 },
}

impl CapacityModel {
    /// Capacity in the uncollapsed regime.
    pub fn base_capacity(&self) -> f64 {
        match *self {
            CapacityModel::Fixed(c) => c,
            CapacityModel::Contended { single, .. } => single,
        }
    }

    /// Capacity given the collapse decision for this resource.
    pub fn capacity(&self, collapsed: bool) -> f64 {
        match *self {
            CapacityModel::Fixed(c) => c,
            CapacityModel::Contended { single, contended } => {
                if collapsed {
                    contended
                } else {
                    single
                }
            }
        }
    }

    pub fn is_contended_model(&self) -> bool {
        matches!(self, CapacityModel::Contended { .. })
    }
}

/// Oversubscription slack before a contended resource collapses.
const COLLAPSE_THRESHOLD: f64 = 1.02;

#[derive(Clone, Debug)]
struct Resource {
    name: String,
    model: CapacityModel,
}

#[derive(Clone, Debug)]
struct Flow {
    path: Vec<ResourceId>,
    bytes: f64,
    remaining: f64,
    rate: f64, // bytes/s, recomputed at each event boundary
    start: SimTime,
    issued: SimTime,
    tag: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A flow transferred its last byte.
    FlowDone { id: FlowId, tag: u64 },
    /// A timer elapsed.
    TimerFired { id: TimerId, tag: u64 },
}

impl Event {
    pub fn tag(&self) -> u64 {
        match self {
            Event::FlowDone { tag, .. } | Event::TimerFired { tag, .. } => *tag,
        }
    }
}

/// Statistics for a completed flow.
#[derive(Clone, Copy, Debug)]
pub struct FlowStats {
    pub issued: SimTime,
    pub started: SimTime,
    pub finished: SimTime,
    pub bytes: f64,
}

impl FlowStats {
    /// Mean throughput over the flow's active (post-setup) phase.
    pub fn throughput(&self) -> f64 {
        if self.finished > self.started {
            self.bytes / (self.finished - self.started)
        } else {
            f64::INFINITY
        }
    }
    /// End-to-end (issue → finish) throughput, including setup latency —
    /// what a `cudaMemcpyAsync` benchmark actually observes (Fig. 6).
    pub fn e2e_throughput(&self) -> f64 {
        if self.finished > self.issued {
            self.bytes / (self.finished - self.issued)
        } else {
            f64::INFINITY
        }
    }
}

/// The simulator.
pub struct FlowSim {
    now: SimTime,
    resources: Vec<Resource>,
    active: HashMap<u64, Flow>,
    /// Flows whose setup latency has not elapsed yet: (activate_at, id, flow).
    pending: Vec<(SimTime, u64, Flow)>,
    timers: Vec<(SimTime, u64, u64)>, // (fire_at, id, tag)
    next_id: u64,
    rates_dirty: bool,
    finished: HashMap<u64, FlowStats>,
    /// Total bytes moved through each resource (utilization accounting).
    resource_bytes: Vec<f64>,
}

impl FlowSim {
    pub fn new() -> Self {
        Self {
            now: 0.0,
            resources: Vec::new(),
            active: HashMap::new(),
            pending: Vec::new(),
            timers: Vec::new(),
            next_id: 0,
            rates_dirty: true,
            finished: HashMap::new(),
            resource_bytes: Vec::new(),
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        self.resources.push(Resource {
            name: name.to_string(),
            model,
        });
        self.resource_bytes.push(0.0);
        ResourceId(self.resources.len() - 1)
    }

    pub fn resource_name(&self, id: ResourceId) -> &str {
        &self.resources[id.0].name
    }

    /// Total bytes that traversed a resource so far.
    pub fn resource_bytes(&self, id: ResourceId) -> f64 {
        self.resource_bytes[id.0]
    }

    /// Start a flow of `bytes` over `path`, activating after `setup`
    /// seconds of latency (DMA setup + device latency). `tag` is an opaque
    /// caller token carried back in the completion event.
    pub fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) -> FlowId {
        assert!(
            !path.is_empty(),
            "flows need ≥1 resource; use timers for pure delays"
        );
        assert!(bytes >= 0.0 && setup >= 0.0);
        for r in path {
            assert!(r.0 < self.resources.len(), "dangling resource id");
        }
        let id = self.next_id;
        self.next_id += 1;
        let flow = Flow {
            path: path.to_vec(),
            bytes,
            remaining: bytes,
            rate: 0.0,
            start: self.now + setup,
            issued: self.now,
            tag,
        };
        if setup > 0.0 {
            self.pending.push((self.now + setup, id, flow));
        } else {
            self.active.insert(id, flow);
            self.rates_dirty = true;
        }
        FlowId(id)
    }

    /// Schedule a timer `delay` seconds from now.
    pub fn add_timer(&mut self, delay: f64, tag: u64) -> TimerId {
        assert!(delay >= 0.0);
        let id = self.next_id;
        self.next_id += 1;
        self.timers.push((self.now + delay, id, tag));
        TimerId(id)
    }

    pub fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id.0).copied()
    }

    pub fn n_active(&self) -> usize {
        self.active.len() + self.pending.len()
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.pending.is_empty() && self.timers.is_empty()
    }

    /// Pure max-min fair ("progressive filling") given per-resource caps.
    /// Returns rate per active flow id.
    fn maxmin(&self, caps: &[f64]) -> HashMap<u64, f64> {
        let mut rates = HashMap::with_capacity(self.active.len());
        if self.active.is_empty() {
            return rates;
        }
        let mut rem_cap = caps.to_vec();
        let mut unassigned: Vec<u64> = {
            let mut v: Vec<u64> = self.active.keys().copied().collect();
            v.sort_unstable(); // determinism
            v
        };
        let mut n_unassigned = vec![0usize; self.resources.len()];
        while !unassigned.is_empty() {
            for c in n_unassigned.iter_mut() {
                *c = 0;
            }
            for id in &unassigned {
                for r in &self.active[id].path {
                    n_unassigned[r.0] += 1;
                }
            }
            // bottleneck resource = min fair share among resources w/ flows
            let mut best: Option<(usize, f64)> = None;
            for (ri, &n) in n_unassigned.iter().enumerate() {
                if n > 0 {
                    let share = (rem_cap[ri] / n as f64).max(0.0);
                    if best.map_or(true, |(_, s)| share < s) {
                        best = Some((ri, share));
                    }
                }
            }
            let Some((bottleneck, share)) = best else { break };
            // fix the rate of all unassigned flows through the bottleneck
            let (through, rest): (Vec<u64>, Vec<u64>) = unassigned
                .iter()
                .partition(|id| self.active[id].path.iter().any(|r| r.0 == bottleneck));
            for id in &through {
                rates.insert(*id, share);
                for r in &self.active[id].path {
                    rem_cap[r.0] = (rem_cap[r.0] - share).max(0.0);
                }
            }
            unassigned = rest;
        }
        rates
    }

    /// Rate assignment with the load-dependent CXL collapse: first decide,
    /// per contended resource, whether its offered load (max-min rates with
    /// that resource uncapped) exceeds its base capacity; then solve the
    /// final max-min with collapsed resources at their degraded capacity.
    fn recompute_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        self.rates_dirty = false;
        if self.active.is_empty() {
            return;
        }
        let base_caps: Vec<f64> = self.resources.iter().map(|r| r.model.base_capacity()).collect();
        // count flows per contended resource
        let mut count = vec![0usize; self.resources.len()];
        for f in self.active.values() {
            for r in &f.path {
                count[r.0] += 1;
            }
        }
        let mut collapsed = vec![false; self.resources.len()];
        for ri in 0..self.resources.len() {
            if !self.resources[ri].model.is_contended_model() || count[ri] < 2 {
                continue;
            }
            // offered load = what the flows would pull if this link were free
            let mut caps_inf = base_caps.clone();
            caps_inf[ri] = f64::INFINITY;
            let rates_inf = self.maxmin(&caps_inf);
            let offered: f64 = self
                .active
                .iter()
                .filter(|(_, f)| f.path.iter().any(|r| r.0 == ri))
                .map(|(id, _)| rates_inf.get(id).copied().unwrap_or(0.0))
                .sum();
            if offered > base_caps[ri] * COLLAPSE_THRESHOLD {
                collapsed[ri] = true;
            }
        }
        let final_caps: Vec<f64> = self
            .resources
            .iter()
            .enumerate()
            .map(|(i, r)| r.model.capacity(collapsed[i]))
            .collect();
        let rates = self.maxmin(&final_caps);
        for (id, f) in self.active.iter_mut() {
            f.rate = rates.get(id).copied().unwrap_or(0.0);
        }
    }

    /// Advance to and return the next event; `None` when idle.
    pub fn next_event(&mut self) -> Option<Event> {
        loop {
            self.recompute_rates();
            // earliest completion among active flows (ties → smallest id)
            let mut t_complete = f64::INFINITY;
            let mut who: Option<u64> = None;
            for (id, f) in &self.active {
                let t = if f.remaining <= 0.0 {
                    self.now
                } else if f.rate > 0.0 {
                    self.now + f.remaining / f.rate
                } else {
                    f64::INFINITY
                };
                if t < t_complete || (t == t_complete && who.map_or(true, |w| *id < w)) {
                    t_complete = t;
                    who = Some(*id);
                }
            }
            let t_activate = self
                .pending
                .iter()
                .map(|(t, _, _)| *t)
                .fold(f64::INFINITY, f64::min);
            let t_timer = self
                .timers
                .iter()
                .map(|(t, _, _)| *t)
                .fold(f64::INFINITY, f64::min);

            let t_next = t_complete.min(t_activate).min(t_timer);
            if !t_next.is_finite() {
                assert!(
                    self.active.is_empty(),
                    "deadlock: active flows with zero rate and nothing pending"
                );
                return None;
            }

            // Drain transferred bytes up to t_next.
            let dt = (t_next - self.now).max(0.0);
            if dt > 0.0 {
                let ids: Vec<u64> = self.active.keys().copied().collect();
                for id in ids {
                    let (moved, path) = {
                        let f = &self.active[&id];
                        (f.rate * dt, f.path.clone())
                    };
                    let f = self.active.get_mut(&id).unwrap();
                    f.remaining = (f.remaining - moved).max(0.0);
                    for r in path {
                        self.resource_bytes[r.0] += moved;
                    }
                }
            }
            self.now = t_next;

            // Activations first (internal — loop again for a visible event).
            if t_activate <= t_timer && t_activate <= t_complete && t_activate.is_finite() {
                let idx = self
                    .pending
                    .iter()
                    .enumerate()
                    .min_by(|(_, (ta, ia, _)), (_, (tb, ib, _))| {
                        (*ta, *ia).partial_cmp(&(*tb, *ib)).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                let (_, id, flow) = self.pending.swap_remove(idx);
                self.active.insert(id, flow);
                self.rates_dirty = true;
                continue;
            }

            // Timers before completions at equal timestamps (a timer set for
            // the same instant a transfer ends should observe the pre-completion
            // state; deterministic either way, this order is just fixed).
            if t_timer <= t_complete && t_timer.is_finite() {
                let idx = self
                    .timers
                    .iter()
                    .enumerate()
                    .min_by(|(_, (ta, ia, _)), (_, (tb, ib, _))| {
                        (*ta, *ia).partial_cmp(&(*tb, *ib)).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                let (_, id, tag) = self.timers.swap_remove(idx);
                return Some(Event::TimerFired { id: TimerId(id), tag });
            }

            // Completion.
            let id = who.expect("completion without candidate flow");
            let f = self.active.remove(&id).unwrap();
            self.rates_dirty = true;
            self.finished.insert(
                id,
                FlowStats {
                    issued: f.issued,
                    started: f.start,
                    finished: self.now,
                    bytes: f.bytes,
                },
            );
            return Some(Event::FlowDone { id: FlowId(id), tag: f.tag });
        }
    }

    /// Run until idle, returning all events in order.
    pub fn run_to_idle(&mut self) -> Vec<Event> {
        let mut out = Vec::new();
        while let Some(e) = self.next_event() {
            out.push(e);
        }
        out
    }
}

impl Default for FlowSim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    #[test]
    fn single_flow_exact_time() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[link], 5.0 * GB, 0.0, 1);
        let events = sim.run_to_idle();
        assert_eq!(events, vec![Event::FlowDone { id: f, tag: 1 }]);
        assert!((sim.now() - 0.5).abs() < 1e-12);
        let st = sim.stats(f).unwrap();
        assert!((st.throughput() - 10.0 * GB).abs() / GB < 1e-9);
    }

    #[test]
    fn setup_latency_delays_completion() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[link], 1.0 * GB, 0.25, 0);
        sim.run_to_idle();
        let st = sim.stats(f).unwrap();
        assert!((st.finished - 0.35).abs() < 1e-12);
        // e2e throughput is lower than active throughput
        assert!(st.e2e_throughput() < st.throughput());
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let a = sim.start_flow(&[link], 5.0 * GB, 0.0, 1);
        let b = sim.start_flow(&[link], 5.0 * GB, 0.0, 2);
        sim.run_to_idle();
        // both at 5 GB/s → both finish at t=1.0
        assert!((sim.stats(a).unwrap().finished - 1.0).abs() < 1e-9);
        assert!((sim.stats(b).unwrap().finished - 1.0).abs() < 1e-9);
    }

    #[test]
    fn short_flow_releases_bandwidth() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let small = sim.start_flow(&[link], 1.0 * GB, 0.0, 1);
        let big = sim.start_flow(&[link], 9.0 * GB, 0.0, 2);
        sim.run_to_idle();
        // phase 1: both at 5 GB/s until small done at t=0.2 (1GB/5GB/s)
        assert!((sim.stats(small).unwrap().finished - 0.2).abs() < 1e-9);
        // big: 1 GB done in phase 1, then 8 GB at 10 GB/s → t = 0.2 + 0.8
        assert!((sim.stats(big).unwrap().finished - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multi_resource_path_takes_min() {
        let mut sim = FlowSim::new();
        let fast = sim.add_resource("fast", CapacityModel::Fixed(100.0 * GB));
        let slow = sim.add_resource("slow", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[fast, slow], 10.0 * GB, 0.0, 0);
        sim.run_to_idle();
        assert!((sim.stats(f).unwrap().finished - 1.0).abs() < 1e-9);
    }

    #[test]
    fn contended_capacity_collapses_aggregate() {
        // Fig. 6b shape: one flow gets `single`; two flows share `contended`
        // (< single) so the aggregate DROPS when a second GPU joins.
        let mut sim = FlowSim::new();
        let aic = sim.add_resource(
            "aic",
            CapacityModel::Contended {
                single: 54.0 * GB,
                contended: 26.0 * GB,
            },
        );
        let g0 = sim.add_resource("gpu0", CapacityModel::Fixed(54.0 * GB));
        let g1 = sim.add_resource("gpu1", CapacityModel::Fixed(54.0 * GB));
        let a = sim.start_flow(&[aic, g0], 13.0 * GB, 0.0, 0);
        let b = sim.start_flow(&[aic, g1], 13.0 * GB, 0.0, 1);
        sim.run_to_idle();
        // each gets 13 GB/s → 26 GB total at 26 GB/s aggregate → 1.0 s
        assert!((sim.stats(a).unwrap().finished - 1.0).abs() < 1e-9);
        assert!((sim.stats(b).unwrap().finished - 1.0).abs() < 1e-9);
        // solo flow for comparison
        let mut sim2 = FlowSim::new();
        let aic2 = sim2.add_resource(
            "aic",
            CapacityModel::Contended {
                single: 54.0 * GB,
                contended: 26.0 * GB,
            },
        );
        let g = sim2.add_resource("gpu", CapacityModel::Fixed(54.0 * GB));
        let solo = sim2.start_flow(&[aic2, g], 13.0 * GB, 0.0, 0);
        sim2.run_to_idle();
        let solo_tp = sim2.stats(solo).unwrap().throughput();
        assert!(solo_tp > 26.0 * GB, "single stream should beat contended aggregate");
    }

    #[test]
    fn max_min_fairness_three_flows_two_links() {
        // Classic max-min example: flows A(link1), B(link1+link2), C(link2);
        // cap(link1)=10, cap(link2)=4. B is bottlenecked on link2 → B=C=2;
        // A gets the rest of link1 → 8.
        let mut sim = FlowSim::new();
        let l1 = sim.add_resource("l1", CapacityModel::Fixed(10.0));
        let l2 = sim.add_resource("l2", CapacityModel::Fixed(4.0));
        // Use huge byte counts and inspect instantaneous rates via first completion
        let a = sim.start_flow(&[l1], 8.0, 0.0, 0);
        let b = sim.start_flow(&[l1, l2], 2.0, 0.0, 1);
        let c = sim.start_flow(&[l2], 2.0, 0.0, 2);
        sim.run_to_idle();
        // with rates A=8,B=2,C=2 all complete exactly at t=1
        for f in [a, b, c] {
            assert!(
                (sim.stats(f).unwrap().finished - 1.0).abs() < 1e-9,
                "flow {f:?} finished at {}",
                sim.stats(f).unwrap().finished
            );
        }
    }

    #[test]
    fn timers_interleave_with_flows() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(1.0 * GB));
        sim.start_flow(&[link], 1.0 * GB, 0.0, 10);
        sim.add_timer(0.5, 20);
        sim.add_timer(2.0, 30);
        let events = sim.run_to_idle();
        let tags: Vec<u64> = events.iter().map(|e| e.tag()).collect();
        assert_eq!(tags, vec![20, 10, 30]);
        assert!((sim.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_flow_completes_after_setup() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(1.0));
        let f = sim.start_flow(&[link], 0.0, 0.125, 0);
        sim.run_to_idle();
        assert!((sim.stats(f).unwrap().finished - 0.125).abs() < 1e-12);
    }

    #[test]
    fn resource_byte_accounting_conserves() {
        let mut sim = FlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(7.0 * GB));
        sim.start_flow(&[link], 3.0 * GB, 0.0, 0);
        sim.start_flow(&[link], 4.0 * GB, 0.1, 1);
        sim.run_to_idle();
        assert!((sim.resource_bytes(link) - 7.0 * GB).abs() / GB < 1e-6);
    }

    #[test]
    fn deterministic_event_order() {
        let run = || {
            let mut sim = FlowSim::new();
            let l = sim.add_resource("l", CapacityModel::Fixed(1.0));
            for i in 0..10 {
                sim.start_flow(&[l], 1.0, 0.0, i);
            }
            sim.run_to_idle().iter().map(|e| e.tag()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn interactive_dependent_flows() {
        // Start flow B only after flow A completes (the engine's pattern).
        let mut sim = FlowSim::new();
        let l = sim.add_resource("l", CapacityModel::Fixed(2.0));
        sim.start_flow(&[l], 2.0, 0.0, 1);
        let e = sim.next_event().unwrap();
        assert_eq!(e.tag(), 1);
        assert!((sim.now() - 1.0).abs() < 1e-12);
        sim.start_flow(&[l], 4.0, 0.0, 2);
        let e2 = sim.next_event().unwrap();
        assert_eq!(e2.tag(), 2);
        assert!((sim.now() - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "flows need")]
    fn empty_path_rejected() {
        let mut sim = FlowSim::new();
        sim.start_flow(&[], 1.0, 0.0, 0);
    }
}
