//! The **pre-refactor** flow simulator, frozen verbatim as a reference
//! oracle and benchmark baseline.
//!
//! [`RefFlowSim`] is the HashMap/linear-scan engine the optimized
//! [`super::flow::FlowSim`] replaced: `active`/`finished` are HashMaps,
//! `pending`/`timers` are Vecs with O(n) min-scans, `maxmin` allocates and
//! sorts fresh id vectors per call, and the drain loop clones every flow's
//! path. It is kept (not deleted) for two reasons:
//!
//! 1. **Determinism contract** — `rust/tests/golden_trace.rs` drives both
//!    engines through identical scenarios and asserts bit-identical event
//!    sequences (ids, tags, `now()` timestamps compared via `to_bits`).
//!    The refactor is only legal because it preserves this contract.
//! 2. **Benchmark baseline** — `benches/sim_hotpath.rs` reports the
//!    events/sec speedup of the slab engine over this one (the acceptance
//!    bar is ≥3× at ≥1e5 flows).
//!
//! One known container-order dependence, preserved as-is: the offered-load
//! sum in `recompute_rates` accumulates in HashMap iteration order, so its
//! low bits may differ run-to-run. It only feeds a 2 % threshold compare,
//! which is why the old engine was observably deterministic anyway; the new
//! engine sums in id order instead (deterministic by construction).
//!
//! Do not optimize or "clean up" this module — its value is being frozen.

use std::collections::HashMap;

use super::flow::{CapacityModel, Event, FlowId, FlowStats, ResourceId, SimTime, TimerId};

/// Oversubscription slack before a contended resource collapses (must match
/// `flow::COLLAPSE_THRESHOLD`).
const COLLAPSE_THRESHOLD: f64 = 1.02;

#[derive(Clone, Debug)]
struct Resource {
    name: String,
    model: CapacityModel,
}

#[derive(Clone, Debug)]
struct Flow {
    path: Vec<ResourceId>,
    bytes: f64,
    remaining: f64,
    rate: f64, // bytes/s, recomputed at each event boundary
    start: SimTime,
    issued: SimTime,
    tag: u64,
}

/// The pre-refactor simulator (see module docs).
pub struct RefFlowSim {
    now: SimTime,
    resources: Vec<Resource>,
    active: HashMap<u64, Flow>,
    /// Flows whose setup latency has not elapsed yet: (activate_at, id, flow).
    pending: Vec<(SimTime, u64, Flow)>,
    timers: Vec<(SimTime, u64, u64)>, // (fire_at, id, tag)
    next_id: u64,
    rates_dirty: bool,
    finished: HashMap<u64, FlowStats>,
    /// Total bytes moved through each resource (utilization accounting).
    resource_bytes: Vec<f64>,
}

impl RefFlowSim {
    pub fn new() -> Self {
        Self {
            now: 0.0,
            resources: Vec::new(),
            active: HashMap::new(),
            pending: Vec::new(),
            timers: Vec::new(),
            next_id: 0,
            rates_dirty: true,
            finished: HashMap::new(),
            resource_bytes: Vec::new(),
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn add_resource(&mut self, name: &str, model: CapacityModel) -> ResourceId {
        self.resources.push(Resource {
            name: name.to_string(),
            model,
        });
        self.resource_bytes.push(0.0);
        ResourceId(self.resources.len() - 1)
    }

    pub fn resource_name(&self, id: ResourceId) -> &str {
        &self.resources[id.0].name
    }

    /// Total bytes that traversed a resource so far.
    pub fn resource_bytes(&self, id: ResourceId) -> f64 {
        self.resource_bytes[id.0]
    }

    /// Start a flow of `bytes` over `path`, activating after `setup`
    /// seconds of latency.
    pub fn start_flow(&mut self, path: &[ResourceId], bytes: f64, setup: f64, tag: u64) -> FlowId {
        assert!(
            !path.is_empty(),
            "flows need ≥1 resource; use timers for pure delays"
        );
        assert!(bytes >= 0.0 && setup >= 0.0);
        for r in path {
            assert!(r.0 < self.resources.len(), "dangling resource id");
        }
        let id = self.next_id;
        self.next_id += 1;
        let flow = Flow {
            path: path.to_vec(),
            bytes,
            remaining: bytes,
            rate: 0.0,
            start: self.now + setup,
            issued: self.now,
            tag,
        };
        if setup > 0.0 {
            self.pending.push((self.now + setup, id, flow));
        } else {
            self.active.insert(id, flow);
            self.rates_dirty = true;
        }
        FlowId(id)
    }

    /// Schedule a timer `delay` seconds from now.
    pub fn add_timer(&mut self, delay: f64, tag: u64) -> TimerId {
        assert!(delay >= 0.0);
        let id = self.next_id;
        self.next_id += 1;
        self.timers.push((self.now + delay, id, tag));
        TimerId(id)
    }

    pub fn stats(&self, id: FlowId) -> Option<FlowStats> {
        self.finished.get(&id.0).copied()
    }

    pub fn n_active(&self) -> usize {
        self.active.len() + self.pending.len()
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.pending.is_empty() && self.timers.is_empty()
    }

    /// Pure max-min fair ("progressive filling") given per-resource caps.
    /// Returns rate per active flow id.
    fn maxmin(&self, caps: &[f64]) -> HashMap<u64, f64> {
        let mut rates = HashMap::with_capacity(self.active.len());
        if self.active.is_empty() {
            return rates;
        }
        let mut rem_cap = caps.to_vec();
        let mut unassigned: Vec<u64> = {
            let mut v: Vec<u64> = self.active.keys().copied().collect();
            v.sort_unstable(); // determinism
            v
        };
        let mut n_unassigned = vec![0usize; self.resources.len()];
        while !unassigned.is_empty() {
            for c in n_unassigned.iter_mut() {
                *c = 0;
            }
            for id in &unassigned {
                for r in &self.active[id].path {
                    n_unassigned[r.0] += 1;
                }
            }
            // bottleneck resource = min fair share among resources w/ flows
            let mut best: Option<(usize, f64)> = None;
            for (ri, &n) in n_unassigned.iter().enumerate() {
                if n > 0 {
                    let share = (rem_cap[ri] / n as f64).max(0.0);
                    if best.map_or(true, |(_, s)| share < s) {
                        best = Some((ri, share));
                    }
                }
            }
            let Some((bottleneck, share)) = best else { break };
            // fix the rate of all unassigned flows through the bottleneck
            let (through, rest): (Vec<u64>, Vec<u64>) = unassigned
                .iter()
                .partition(|id| self.active[id].path.iter().any(|r| r.0 == bottleneck));
            for id in &through {
                rates.insert(*id, share);
                for r in &self.active[id].path {
                    rem_cap[r.0] = (rem_cap[r.0] - share).max(0.0);
                }
            }
            unassigned = rest;
        }
        rates
    }

    /// Rate assignment with the load-dependent CXL collapse.
    fn recompute_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        self.rates_dirty = false;
        if self.active.is_empty() {
            return;
        }
        let base_caps: Vec<f64> = self.resources.iter().map(|r| r.model.base_capacity()).collect();
        // count flows per contended resource
        let mut count = vec![0usize; self.resources.len()];
        for f in self.active.values() {
            for r in &f.path {
                count[r.0] += 1;
            }
        }
        let mut collapsed = vec![false; self.resources.len()];
        for ri in 0..self.resources.len() {
            if !self.resources[ri].model.is_contended_model() || count[ri] < 2 {
                continue;
            }
            // offered load = what the flows would pull if this link were free
            let mut caps_inf = base_caps.clone();
            caps_inf[ri] = f64::INFINITY;
            let rates_inf = self.maxmin(&caps_inf);
            let offered: f64 = self
                .active
                .iter()
                .filter(|(_, f)| f.path.iter().any(|r| r.0 == ri))
                .map(|(id, _)| rates_inf.get(id).copied().unwrap_or(0.0))
                .sum();
            if offered > base_caps[ri] * COLLAPSE_THRESHOLD {
                collapsed[ri] = true;
            }
        }
        let final_caps: Vec<f64> = self
            .resources
            .iter()
            .enumerate()
            .map(|(i, r)| r.model.capacity(collapsed[i]))
            .collect();
        let rates = self.maxmin(&final_caps);
        for (id, f) in self.active.iter_mut() {
            f.rate = rates.get(id).copied().unwrap_or(0.0);
        }
    }

    /// Advance to and return the next event; `None` when idle.
    pub fn next_event(&mut self) -> Option<Event> {
        loop {
            self.recompute_rates();
            // earliest completion among active flows (ties → smallest id)
            let mut t_complete = f64::INFINITY;
            let mut who: Option<u64> = None;
            for (id, f) in &self.active {
                let t = if f.remaining <= 0.0 {
                    self.now
                } else if f.rate > 0.0 {
                    self.now + f.remaining / f.rate
                } else {
                    f64::INFINITY
                };
                if t < t_complete || (t == t_complete && who.map_or(true, |w| *id < w)) {
                    t_complete = t;
                    who = Some(*id);
                }
            }
            let t_activate = self
                .pending
                .iter()
                .map(|(t, _, _)| *t)
                .fold(f64::INFINITY, f64::min);
            let t_timer = self
                .timers
                .iter()
                .map(|(t, _, _)| *t)
                .fold(f64::INFINITY, f64::min);

            let t_next = t_complete.min(t_activate).min(t_timer);
            if !t_next.is_finite() {
                assert!(
                    self.active.is_empty(),
                    "deadlock: active flows with zero rate and nothing pending"
                );
                return None;
            }

            // Drain transferred bytes up to t_next.
            let dt = (t_next - self.now).max(0.0);
            if dt > 0.0 {
                let ids: Vec<u64> = self.active.keys().copied().collect();
                for id in ids {
                    let (moved, path) = {
                        let f = &self.active[&id];
                        (f.rate * dt, f.path.clone())
                    };
                    let f = self.active.get_mut(&id).unwrap();
                    f.remaining = (f.remaining - moved).max(0.0);
                    for r in path {
                        self.resource_bytes[r.0] += moved;
                    }
                }
            }
            self.now = t_next;

            // Activations first (internal — loop again for a visible event).
            if t_activate <= t_timer && t_activate <= t_complete && t_activate.is_finite() {
                let idx = self
                    .pending
                    .iter()
                    .enumerate()
                    .min_by(|(_, (ta, ia, _)), (_, (tb, ib, _))| {
                        (*ta, *ia).partial_cmp(&(*tb, *ib)).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                let (_, id, flow) = self.pending.swap_remove(idx);
                self.active.insert(id, flow);
                self.rates_dirty = true;
                continue;
            }

            // Timers before completions at equal timestamps.
            if t_timer <= t_complete && t_timer.is_finite() {
                let idx = self
                    .timers
                    .iter()
                    .enumerate()
                    .min_by(|(_, (ta, ia, _)), (_, (tb, ib, _))| {
                        (*ta, *ia).partial_cmp(&(*tb, *ib)).unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                let (_, id, tag) = self.timers.swap_remove(idx);
                return Some(Event::TimerFired { id: TimerId(id), tag });
            }

            // Completion.
            let id = who.expect("completion without candidate flow");
            let f = self.active.remove(&id).unwrap();
            self.rates_dirty = true;
            self.finished.insert(
                id,
                FlowStats {
                    issued: f.issued,
                    started: f.start,
                    finished: self.now,
                    bytes: f.bytes,
                },
            );
            return Some(Event::FlowDone { id: FlowId(id), tag: f.tag });
        }
    }

    /// Run until idle, returning all events in order.
    pub fn run_to_idle(&mut self) -> Vec<Event> {
        let mut out = Vec::new();
        while let Some(e) = self.next_event() {
            out.push(e);
        }
        out
    }
}

impl Default for RefFlowSim {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    // A couple of smoke tests so a regression in the frozen oracle itself
    // (e.g. a bad merge) is caught close to home; the heavy coverage lives
    // in `flow.rs` (new engine) and `rust/tests/golden_trace.rs` (both).
    #[test]
    fn reference_single_flow_exact_time() {
        let mut sim = RefFlowSim::new();
        let link = sim.add_resource("link", CapacityModel::Fixed(10.0 * GB));
        let f = sim.start_flow(&[link], 5.0 * GB, 0.0, 1);
        let events = sim.run_to_idle();
        assert_eq!(events, vec![Event::FlowDone { id: f, tag: 1 }]);
        assert!((sim.now() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reference_contended_collapse() {
        let mut sim = RefFlowSim::new();
        let aic = sim.add_resource(
            "aic",
            CapacityModel::Contended {
                single: 54.0 * GB,
                contended: 26.0 * GB,
            },
        );
        let g0 = sim.add_resource("gpu0", CapacityModel::Fixed(54.0 * GB));
        let g1 = sim.add_resource("gpu1", CapacityModel::Fixed(54.0 * GB));
        let a = sim.start_flow(&[aic, g0], 13.0 * GB, 0.0, 0);
        let b = sim.start_flow(&[aic, g1], 13.0 * GB, 0.0, 1);
        sim.run_to_idle();
        assert!((sim.stats(a).unwrap().finished - 1.0).abs() < 1e-9);
        assert!((sim.stats(b).unwrap().finished - 1.0).abs() < 1e-9);
    }
}
