//! Training state: fp32 master parameters organized per transformer block
//! (the streaming granularity of the offload workflow) plus embedding and
//! final-norm groups, each with its own Adam state.
//!
//! Shapes come from the artifact manifest, so Rust never hard-codes the
//! model architecture — it mirrors whatever `python/compile/aot.py` lowered.

use anyhow::{bail, Result};

use crate::optim::{AdamHp, AdamState};
use crate::runtime::{HostTensor, Manifest, TensorSpec};
use crate::util::prng::Xoshiro256pp;

/// One block's parameters: named tensors, flat Adam state over the concat.
#[derive(Clone, Debug)]
pub struct BlockParams {
    pub specs: Vec<TensorSpec>,
    /// Flattened concatenation of all tensors, in spec order.
    pub flat: Vec<f32>,
    /// Byte-free offsets into `flat` per tensor.
    pub offsets: Vec<usize>,
    pub adam: AdamState,
}

impl BlockParams {
    fn init(specs: Vec<TensorSpec>, rng: &mut Xoshiro256pp) -> Self {
        let mut flat = Vec::new();
        let mut offsets = Vec::with_capacity(specs.len());
        for s in &specs {
            offsets.push(flat.len());
            let n = s.element_count();
            if s.name.starts_with("ln") || s.name.contains("norm") {
                flat.extend(std::iter::repeat(1.0f32).take(n));
            } else {
                // scaled-normal init: std 0.02 like GPT
                flat.extend((0..n).map(|_| (rng.normal() as f32) * 0.02));
            }
        }
        let adam = AdamState::new(flat.len());
        Self {
            specs,
            flat,
            offsets,
            adam,
        }
    }

    pub fn n_params(&self) -> usize {
        self.flat.len()
    }

    /// View tensor `i` as a HostTensor (copies — tiny model, clarity wins).
    pub fn tensor(&self, i: usize) -> HostTensor {
        let start = self.offsets[i];
        let n = self.specs[i].element_count();
        HostTensor::new(
            self.flat[start..start + n].to_vec(),
            self.specs[i].shape.clone(),
        )
    }

    /// All tensors in order.
    pub fn tensors(&self) -> Vec<HostTensor> {
        (0..self.specs.len()).map(|i| self.tensor(i)).collect()
    }

    /// Flatten per-tensor gradients (same order) into one buffer.
    pub fn flatten_grads(&self, grads: &[HostTensor]) -> Result<Vec<f32>> {
        if grads.len() != self.specs.len() {
            bail!(
                "expected {} grad tensors, got {}",
                self.specs.len(),
                grads.len()
            );
        }
        let mut flat = Vec::with_capacity(self.flat.len());
        for (g, s) in grads.iter().zip(&self.specs) {
            if g.shape != s.shape {
                bail!("grad shape {:?} != param shape {:?} ({})", g.shape, s.shape, s.name);
            }
            flat.extend_from_slice(&g.data);
        }
        Ok(flat)
    }

    /// Adam over the whole block.
    pub fn step(&mut self, grads_flat: &[f32], hp: &AdamHp, threads: usize) {
        crate::optim::adam_step(&mut self.flat, grads_flat, &mut self.adam, hp, threads);
    }
}

/// Whole-model state.
pub struct TrainState {
    pub blocks: Vec<BlockParams>,
    /// Embedding table [V, H] (tied with the LM head).
    pub embed: BlockParams,
    /// Final norm scale [H].
    pub final_norm: BlockParams,
}

impl TrainState {
    /// Initialize from the manifest: block shapes from `block_fwd` inputs
    /// (skipping the leading activation `x`), embedding from `embed_fwd`,
    /// final norm from `head_loss`.
    pub fn init(manifest: &Manifest, seed: u64) -> Result<TrainState> {
        let mut rng = Xoshiro256pp::seeded(seed);
        let layers = manifest.meta_usize("layers")?;
        let block_entry = manifest.entry("block_fwd")?;
        if block_entry.inputs.len() < 2 {
            bail!("block_fwd must take (x, params...)");
        }
        let block_specs: Vec<TensorSpec> = block_entry.inputs[1..].to_vec();
        let blocks = (0..layers)
            .map(|_| BlockParams::init(block_specs.clone(), &mut rng))
            .collect();
        let embed_spec = manifest.entry("embed_fwd")?.inputs[1].clone();
        let embed = BlockParams::init(vec![embed_spec], &mut rng);
        let lnf_spec = manifest.entry("head_loss")?.inputs[1].clone();
        let final_norm = BlockParams::init(vec![lnf_spec], &mut rng);
        Ok(TrainState {
            blocks,
            embed,
            final_norm,
        })
    }

    /// Total trainable parameters.
    pub fn n_params(&self) -> usize {
        self.blocks.iter().map(|b| b.n_params()).sum::<usize>()
            + self.embed.n_params()
            + self.final_norm.n_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fake_manifest() -> Manifest {
        let text = r#"{
          "model": {"layers": 2, "hidden": 8, "vocab": 32},
          "entries": {
            "embed_fwd": {"file": "e.hlo.txt",
              "inputs": [{"name": "ids", "shape": [1, 4], "dtype": "i32"},
                         {"name": "emb", "shape": [32, 8], "dtype": "f32"}],
              "outputs": [{"name": "x", "shape": [1, 4, 8], "dtype": "f32"}]},
            "block_fwd": {"file": "b.hlo.txt",
              "inputs": [{"name": "x", "shape": [1, 4, 8], "dtype": "f32"},
                         {"name": "ln1", "shape": [8], "dtype": "f32"},
                         {"name": "wq", "shape": [8, 8], "dtype": "f32"}],
              "outputs": [{"name": "y", "shape": [1, 4, 8], "dtype": "f32"}]},
            "head_loss": {"file": "h.hlo.txt",
              "inputs": [{"name": "x", "shape": [1, 4, 8], "dtype": "f32"},
                         {"name": "lnf", "shape": [8], "dtype": "f32"},
                         {"name": "emb", "shape": [32, 8], "dtype": "f32"},
                         {"name": "labels", "shape": [1, 4], "dtype": "i32"}],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}
          }
        }"#;
        Manifest::parse(text, PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn init_builds_correct_shapes() {
        let st = TrainState::init(&fake_manifest(), 3).unwrap();
        assert_eq!(st.blocks.len(), 2);
        assert_eq!(st.blocks[0].specs.len(), 2); // ln1, wq
        assert_eq!(st.blocks[0].n_params(), 8 + 64);
        assert_eq!(st.embed.n_params(), 32 * 8);
        assert_eq!(st.final_norm.n_params(), 8);
        assert_eq!(st.n_params(), 2 * 72 + 256 + 8);
    }

    #[test]
    fn norm_tensors_init_to_one_weights_to_small() {
        let st = TrainState::init(&fake_manifest(), 3).unwrap();
        let ln = st.blocks[0].tensor(0);
        assert!(ln.data.iter().all(|&x| x == 1.0));
        let wq = st.blocks[0].tensor(1);
        assert!(wq.data.iter().any(|&x| x != 0.0));
        assert!(wq.data.iter().all(|&x| x.abs() < 0.2));
    }

    #[test]
    fn blocks_get_different_random_init() {
        let st = TrainState::init(&fake_manifest(), 3).unwrap();
        assert_ne!(st.blocks[0].tensor(1).data, st.blocks[1].tensor(1).data);
    }

    #[test]
    fn flatten_grads_validates() {
        let st = TrainState::init(&fake_manifest(), 3).unwrap();
        let good = vec![
            HostTensor::zeros(&[8]),
            HostTensor::zeros(&[8, 8]),
        ];
        let flat = st.blocks[0].flatten_grads(&good).unwrap();
        assert_eq!(flat.len(), st.blocks[0].n_params());
        let bad = vec![HostTensor::zeros(&[8])];
        assert!(st.blocks[0].flatten_grads(&bad).is_err());
    }

    #[test]
    fn step_moves_params() {
        let mut st = TrainState::init(&fake_manifest(), 3).unwrap();
        let before = st.blocks[0].flat.clone();
        let grads = vec![0.1f32; st.blocks[0].n_params()];
        st.blocks[0].step(&grads, &AdamHp::default(), 2);
        assert_ne!(before, st.blocks[0].flat);
        assert_eq!(st.blocks[0].adam.step, 1);
    }
}
