//! The schedule-IR parity lock (ISSUE 3): the declarative `zero-offload`
//! schedule run through the generic executor must reproduce the FROZEN
//! legacy iteration engine (`offload::iteration`) **byte-for-byte** —
//! span-for-span identical traces (names, lanes, `to_bits` timestamps, in
//! recording order) and bitwise-equal phase breakdowns — across the
//! paper's Fig. 7/9/10 cells, both topologies, every placement policy and
//! several prefetch depths.
//!
//! New-schedule behavior is locked the same way the DES refactor was:
//! golden digests under `rust/tests/golden/` (self-blessing on the first
//! toolchain run — this repo is authored in a container without cargo),
//! plus semantic assertions that do not depend on blessed files.

mod common;

use cxlfine::mem::Policy;
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::model::ModelConfig;
use cxlfine::offload::{
    legacy_simulate_iteration_traced, schedules, simulate_iteration_report,
    simulate_iteration_traced, MemoryPlan, RunConfig,
};
use cxlfine::topology::presets::{config_a, config_b, with_dram_capacity};
use cxlfine::topology::SystemTopology;
use cxlfine::util::units::GIB;

// ---------------------------------------------------------------------
// Differential lock: schedule executor vs the frozen legacy engine.
// ---------------------------------------------------------------------

fn assert_parity(
    what: &str,
    topo: &SystemTopology,
    model: ModelConfig,
    w: Workload,
    policy: Policy,
    prefetch_depth: usize,
) {
    let mut cfg = RunConfig::new(model, w, policy);
    cfg.prefetch_depth = prefetch_depth;
    let plan = MemoryPlan::build(topo, &cfg).expect("cell must fit");

    let (legacy_bd, legacy_trace) = legacy_simulate_iteration_traced(topo, &cfg, &plan);
    let (new_bd, new_trace) = simulate_iteration_traced(topo, &cfg, &plan);

    // Span-for-span equality with a pinpointing error message before the
    // digest (which would only say "something differs").
    assert_eq!(
        new_trace.spans().len(),
        legacy_trace.spans().len(),
        "{what}: span counts diverge"
    );
    for (i, (n, l)) in new_trace
        .spans()
        .iter()
        .zip(legacy_trace.spans())
        .enumerate()
    {
        assert!(
            n.name == l.name
                && n.lane == l.lane
                && n.start_s.to_bits() == l.start_s.to_bits()
                && n.end_s.to_bits() == l.end_s.to_bits(),
            "{what}: span #{i} diverges — new {n:?} vs legacy {l:?}"
        );
    }
    assert_eq!(
        new_trace.digest(),
        legacy_trace.digest(),
        "{what}: trace digests diverge"
    );

    for (field, a, b) in [
        ("fwd_s", new_bd.fwd_s, legacy_bd.fwd_s),
        ("bwd_s", new_bd.bwd_s, legacy_bd.bwd_s),
        ("step_s", new_bd.step_s, legacy_bd.step_s),
        ("iter_s", new_bd.iter_s, legacy_bd.iter_s),
    ] {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: breakdown {field} diverges ({a} vs {b})"
        );
    }
    assert_eq!(new_bd.tokens, legacy_bd.tokens, "{what}: tokens diverge");
}

#[test]
fn parity_fig9_cell_cxl_aware() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    assert_parity(
        "fig9 qwen7b 1x8x4096 cxl-aware",
        &topo,
        qwen25_7b(),
        Workload::new(1, 8, 4096),
        Policy::CxlAware { striping: false },
        2,
    );
}

#[test]
fn parity_fig7_cell_naive_breakdown() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    assert_parity(
        "fig7a nemo12b 1x16x4096 naive",
        &topo,
        mistral_nemo_12b(),
        Workload::new(1, 16, 4096),
        Policy::NaiveInterleave,
        2,
    );
}

#[test]
fn parity_fig7b_transfer_bound_dual_gpu() {
    // B=1 is the most transfer-bound cell the paper probes — the hardest
    // case for issuance-order parity because kernels barely hide flows.
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    assert_parity(
        "fig7b nemo12b 2x1x4096 naive",
        &topo,
        mistral_nemo_12b(),
        Workload::new(2, 1, 4096),
        Policy::NaiveInterleave,
        2,
    );
}

#[test]
fn parity_fig10_cell_dual_aic_striping() {
    let topo = with_dram_capacity(config_b(), 128 * GIB);
    assert_parity(
        "fig10 nemo12b 2x16x4096 striped",
        &topo,
        mistral_nemo_12b(),
        Workload::new(2, 16, 4096),
        Policy::CxlAware { striping: true },
        2,
    );
}

#[test]
fn parity_dram_baseline_dual_gpu() {
    let topo = config_a();
    assert_parity(
        "baseline qwen7b 2x4x4096 dram",
        &topo,
        qwen25_7b(),
        Workload::new(2, 4, 4096),
        Policy::DramOnly,
        2,
    );
}

#[test]
fn parity_across_prefetch_depths() {
    // Depth changes the prefetch-window shape (and therefore the whole
    // issuance interleave); the builder must track the legacy engine at
    // every depth, including depth > layers on the shallow 7B model.
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    for depth in [1, 3, 64] {
        assert_parity(
            &format!("qwen7b 1x8x4096 cxl-aware depth={depth}"),
            &topo,
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::CxlAware { striping: false },
            depth,
        );
    }
}

#[test]
fn parity_adaptive_spill_engine() {
    // A post-paper engine exercises different stripe fractions through the
    // same schedule.
    let topo = with_dram_capacity(config_b(), 128 * GIB);
    let mut cfg = RunConfig::new(
        qwen25_7b(),
        Workload::new(2, 8, 4096),
        cxlfine::mem::engine::by_name("adaptive-spill").unwrap(),
    );
    cfg.prefetch_depth = 2;
    let plan = MemoryPlan::build(&topo, &cfg).expect("fits");
    let (legacy_bd, legacy_trace) = legacy_simulate_iteration_traced(&topo, &cfg, &plan);
    let (new_bd, new_trace) = simulate_iteration_traced(&topo, &cfg, &plan);
    assert_eq!(new_trace.digest(), legacy_trace.digest());
    assert_eq!(new_bd.iter_s.to_bits(), legacy_bd.iter_s.to_bits());
}

// ---------------------------------------------------------------------
// Satellite: per-GPU kernel pricing (heterogeneous fleets).
// ---------------------------------------------------------------------

#[test]
fn heterogeneous_fleet_slow_gpu_lengthens_only_its_lane() {
    // The legacy engine priced every GPU at gpus[0]'s rating; the executor
    // prices each kernel with its own GPU. Halve GPU 1's MFU and check the
    // slowdown stays in its lane.
    let mut topo = config_a();
    topo.gpus[1].mfu /= 2.0;
    let cfg = RunConfig::new(qwen25_7b(), Workload::new(2, 4, 4096), Policy::DramOnly);
    let plan = MemoryPlan::build(&topo, &cfg).unwrap();
    let (report, trace) = simulate_iteration_report(&topo, &cfg, &plan);

    let busy = |lane: &str| {
        trace
            .lane_busy()
            .into_iter()
            .find(|(l, _)| l == lane)
            .map(|(_, b)| b)
            .unwrap_or_else(|| panic!("lane {lane} missing"))
    };
    let fast = busy("gpu0/compute");
    let slow = busy("gpu1/compute");
    assert!(
        (slow / fast - 2.0).abs() < 1e-9,
        "halved MFU must exactly double gpu1's compute time: {fast} vs {slow}"
    );

    // gpu0's kernels are priced identically to the homogeneous machine
    let homo = config_a();
    let plan_h = MemoryPlan::build(&homo, &cfg).unwrap();
    let (_, trace_h) = simulate_iteration_report(&homo, &cfg, &plan_h);
    let fast_h = trace_h
        .lane_busy()
        .into_iter()
        .find(|(l, _)| l == "gpu0/compute")
        .map(|(_, b)| b)
        .unwrap();
    assert_eq!(
        fast.to_bits(),
        fast_h.to_bits(),
        "the fast GPU's own kernel time must be untouched"
    );

    // ...and the legacy engine demonstrably got this wrong: it priced the
    // slow GPU at gpu0's rating, finishing impossibly early.
    let (legacy_bd, _) = legacy_simulate_iteration_traced(&topo, &cfg, &plan);
    assert!(
        report.iter_s > legacy_bd.iter_s,
        "executor must charge the slow GPU honestly (new {} vs legacy {})",
        report.iter_s,
        legacy_bd.iter_s
    );
}

// ---------------------------------------------------------------------
// Golden digests for the new schedules (self-blessing, like PR 2's).
// ---------------------------------------------------------------------

fn assert_golden_digest(name: &str, digest: u64) {
    common::assert_golden_digest("schedule_parity", name, digest);
}

fn schedule_cell_digest(schedule: &str) -> u64 {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let cfg = RunConfig::new(
        qwen25_7b(),
        Workload::new(1, 4, 4096),
        Policy::CxlAware { striping: false },
    )
    .with_schedule(schedules::by_name(schedule).unwrap());
    let plan = MemoryPlan::build(&topo, &cfg).unwrap();
    let (_, trace) = simulate_iteration_report(&topo, &cfg, &plan);
    assert!(!trace.is_empty());
    trace.digest()
}

#[test]
fn golden_schedule_grad_accum() {
    assert_golden_digest("sched_grad_accum2_qwen7b_c4096_b4", schedule_cell_digest("grad-accum:2"));
}

#[test]
fn golden_schedule_lora() {
    assert_golden_digest("sched_lora16_qwen7b_c4096_b4", schedule_cell_digest("lora:16"));
}

#[test]
fn golden_schedule_no_act_offload() {
    assert_golden_digest(
        "sched_no_act_offload_qwen7b_c4096_b4",
        schedule_cell_digest("no-act-offload"),
    );
}

// ---------------------------------------------------------------------
// Cross-schedule semantics at paper scale.
// ---------------------------------------------------------------------

#[test]
fn new_schedules_relate_sanely_at_paper_scale() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let mk = |sched: &str| {
        let cfg = RunConfig::new(
            qwen25_7b(),
            Workload::new(1, 4, 4096),
            Policy::CxlAware { striping: false },
        )
        .with_schedule(schedules::by_name(sched).unwrap());
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        simulate_iteration_report(&topo, &cfg, &plan).0
    };
    let zo = mk("zero-offload");
    let ga = mk("grad-accum:2");
    let lo = mk("lora");
    let na = mk("no-act-offload");

    // grad accumulation: 2× tokens, one step → better tokens/s than two
    // separate iterations, never better than 2× the work in 1× the time
    assert_eq!(ga.tokens, 2 * zo.tokens);
    assert!(ga.iter_s > zo.iter_s && ga.iter_s < 2.0 * zo.iter_s);
    assert!(ga.tokens_per_sec() > zo.tokens_per_sec());
    // accumulation interleaves phases — the overlap satellite at scale
    assert!(ga.overlaps("fwd", "bwd"));
    assert!(!zo.overlaps("bwd", "step"));

    // LoRA: the optimizer's working set collapses, STEP nearly vanishes
    let zo_bd = zo.to_breakdown();
    let lo_bd = lo.to_breakdown();
    assert!(
        lo_bd.step_s < 0.1 * zo_bd.step_s,
        "lora step {} vs full {}",
        lo_bd.step_s,
        zo_bd.step_s
    );
    assert!(lo_bd.iter_s < zo_bd.iter_s);

    // the activation ablation only removes traffic
    assert!(na.iter_s <= zo.iter_s * (1.0 + 1e-9));
}
