//! `simcore` — the one event core under both discrete-event layers
//! (DESIGN.md §14; ROADMAP item 3).
//!
//! Before this module the repo ran two independent event engines: the
//! FlowSim slab engine (`sim::flow`, PR 2) and the fleet simulator's
//! serial `BinaryHeap` loop (`fleet::sim`, PRs 5/7). Both now sit on the
//! same four primitives:
//!
//! * [`Slab`] — dense `u32`-indexed entity store with free-list
//!   recycling; stable identities are caller fields, indices recycle.
//! * [`EventKey`] — the shared `time_bits · kind · seq` key encoding.
//!   Replaces FlowSim's `OrdTime` wrapper and the fleet's
//!   `Reverse<(u64, u8, u64, usize)>` tuples; the fleet's pinned ordering
//!   (completions < faults < arrivals < requeues) survives as kind ranks.
//! * [`EventQueue`] — binary-heap and calendar-queue (time-wheel)
//!   backends behind one key-ordered interface, observationally
//!   bit-identical; [`BackendPolicy::Auto`] upgrades to the wheel for
//!   timer-heavy mixes. `pop_cohort` drains the full equal-timestamp
//!   cohort so the layers apply same-time events batched (one rate
//!   recompute / one admission pass per cohort, not per event).
//! * [`lanes`] — deterministic parallel lanes: value-pure indexed
//!   fan-outs merged in item order, the contract that keeps `--threads`
//!   digest-invariant.
//!
//! The adapters: `sim::flow::FlowSim` (and `Fabric` above it) and
//! `fleet::sim::simulate_fleet_faulted` are thin layers over this core;
//! `sim::reference` and `fleet::reference` stay frozen as differential
//! oracles (`rust/tests/golden_trace.rs`, `rust/tests/simcore_parity.rs`).

pub mod key;
pub mod lanes;
pub mod queue;
pub mod slab;

pub use key::EventKey;
pub use queue::{BackendPolicy, EventQueue};
pub use slab::Slab;
