//! Shared helpers for the golden-digest suites (`golden_trace.rs`,
//! `schedule_parity.rs`); each test binary compiles this module
//! independently via `mod common;`.

use std::path::{Path, PathBuf};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

/// Compare `digest` against `rust/tests/golden/<name>.digest`, blessing
/// the file on first run (see `rust/tests/golden/README.md`). Blessed
/// files make the sequence a hard regression gate for every later build,
/// including across debug/release profiles (digests contain only
/// IEEE-754-deterministic arithmetic). `suite` labels the blessing log.
pub fn assert_golden_digest(suite: &str, name: &str, digest: u64) {
    let dir = golden_dir();
    let path = dir.join(format!("{name}.digest"));
    let hex = format!("{digest:016x}");
    match std::fs::read_to_string(&path) {
        Ok(recorded) => {
            assert_eq!(
                recorded.trim(),
                hex,
                "golden trace digest changed for '{name}' — the recorded \
                 event sequence is no longer byte-identical. If the change \
                 is intentional, delete {} and re-run to re-bless.",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(&dir).ok();
            std::fs::write(&path, format!("{hex}\n"))
                .unwrap_or_else(|e| panic!("cannot bless golden digest {}: {e}", path.display()));
            eprintln!("[{suite}] blessed '{name}' = {hex}");
        }
    }
}
