//! The long-lived multi-job host: one [`NumaAllocator`] shared by every
//! resident job, plus GPU-slot accounting and *effective* (degradable)
//! per-node capacities.
//!
//! Each admitted job is one committed region (its [`PlanReservation`]
//! shards, one per node) named `job-<id>`; completion releases it through
//! [`NumaAllocator::release_region`], restoring free space byte-identically
//! to the job never having run. Admission plans are built against a
//! *capacity view*: a clone of the host topology whose node capacities
//! equal the current free bytes, so the existing placement engines and
//! capacity arithmetic do all the work unchanged. [`FleetHost::free_view`]
//! is the one-shot form of that view; the simulator's probe keeps its own
//! scratch clone and rewrites only the capacities per attempt (same
//! semantics, no per-attempt deep clone).
//!
//! Fault support: [`FleetHost::set_capacity`] overrides one node's
//! effective capacity (AIC hot-remove → 0, capacity squeeze → reduced,
//! restore → back up), which `free()` and `free_view()` immediately
//! reflect; the allocator's committed bytes are untouched, so a fault can
//! transiently leave a node *over* its effective capacity until the
//! simulator evicts or evacuates the victims. [`FleetHost::release_memory`]
//! / [`FleetHost::reserve_memory`] split a job's residency into its memory
//! half (regions move during an evacuation while the job keeps its GPUs)
//! and [`FleetHost::residents_on`] names the victims a node fault touches.

use std::collections::BTreeMap;

use crate::mem::{AllocError, NumaAllocator, Placement, Policy, RegionId, RegionRequest, TensorClass};
use crate::offload::PlanReservation;
use crate::sim::memmodel::AccessMode;
use crate::topology::{presets as tpresets, SystemTopology};

pub struct FleetHost<'t> {
    topo: &'t SystemTopology,
    alloc: NumaAllocator<'t>,
    /// Committed reservation per resident job id (region handle + the
    /// per-node shards, kept so faults can price bytes-on-node without
    /// reaching into allocator internals).
    by_job: BTreeMap<u64, (RegionId, PlanReservation)>,
    /// GPUs currently assigned to per-job reservations.
    gpus_in_use: usize,
    /// Effective capacity per node — the pristine topology capacity until
    /// a fault overrides it.
    eff_caps: Vec<u64>,
}

impl<'t> FleetHost<'t> {
    pub fn new(topo: &'t SystemTopology) -> Self {
        Self {
            topo,
            // The engine is irrelevant: the host only `commit`s explicit
            // reservations computed by admission plans, never `alloc`s.
            alloc: NumaAllocator::new(topo, Policy::DramOnly),
            by_job: BTreeMap::new(),
            gpus_in_use: 0,
            eff_caps: topo.mem_nodes.iter().map(|n| n.capacity).collect(),
        }
    }

    pub fn topo(&self) -> &'t SystemTopology {
        self.topo
    }

    /// Override one node's effective capacity (fault events); `free()`
    /// and `free_view()` reflect it immediately. Committed bytes are
    /// untouched — the caller evicts/evacuates any overshoot.
    pub fn set_capacity(&mut self, node: usize, bytes: u64) {
        self.eff_caps[node] = bytes;
    }

    /// Free bytes per node under the *effective* capacities, indexed by
    /// `NodeId.0`. A node holding more than its (degraded) effective
    /// capacity reports zero free, never underflows.
    pub fn free(&self) -> Vec<u64> {
        self.topo
            .all_nodes()
            .iter()
            .map(|&n| self.eff_caps[n.0].saturating_sub(self.alloc.used_on(n)))
            .collect()
    }

    /// Used bytes per node, indexed by `NodeId.0`.
    pub fn used(&self) -> Vec<u64> {
        self.topo
            .all_nodes()
            .iter()
            .map(|&n| self.alloc.used_on(n))
            .collect()
    }

    pub fn free_gpus(&self) -> usize {
        self.topo.gpus.len() - self.gpus_in_use
    }

    /// Clone of the host topology with capacities set to the current free
    /// bytes — the one-shot capacity view admission plans are built
    /// against (the simulator's probe maintains the same view
    /// incrementally in a scratch clone). Nodes may carry zero capacity,
    /// so the clone is deliberately not re-validated.
    pub fn free_view(&self) -> SystemTopology {
        tpresets::with_node_capacities(self.topo.clone(), &self.free())
    }

    pub fn n_resident(&self) -> usize {
        self.by_job.len()
    }

    /// The committed reservation of a resident job.
    pub fn reservation(&self, job_id: u64) -> Option<&PlanReservation> {
        self.by_job.get(&job_id).map(|(_, r)| r)
    }

    /// Resident jobs holding bytes on `node`, as `(job_id, bytes_on_node)`
    /// in ascending job-id order — the victim set of a node fault.
    pub fn residents_on(&self, node: usize) -> Vec<(u64, u64)> {
        self.by_job
            .iter()
            .filter_map(|(id, (_, res))| {
                let bytes = res.bytes_on(crate::topology::NodeId(node));
                (bytes > 0).then_some((*id, bytes))
            })
            .collect()
    }

    /// Commit a job's reservation (memory shards + GPU slots) for its
    /// whole residency.
    pub fn reserve(
        &mut self,
        job_id: u64,
        reservation: &PlanReservation,
        gpus: usize,
    ) -> Result<(), AllocError> {
        assert!(
            gpus <= self.free_gpus(),
            "job {job_id} wants {gpus} GPUs, {} free",
            self.free_gpus()
        );
        self.reserve_memory(job_id, reservation)?;
        self.gpus_in_use += gpus;
        Ok(())
    }

    /// Commit only the memory half of a residency (re-commit after an
    /// evacuation re-plan: the job keeps the GPUs it already holds).
    pub fn reserve_memory(
        &mut self,
        job_id: u64,
        reservation: &PlanReservation,
    ) -> Result<(), AllocError> {
        assert!(
            !self.by_job.contains_key(&job_id),
            "job {job_id} is already resident"
        );
        let placement = Placement {
            parts: reservation.parts.clone(),
            mode: AccessMode::Partitioned,
        };
        let id = self.alloc.commit(
            RegionRequest::new(
                format!("job-{job_id}"),
                TensorClass::Activations,
                reservation.total_bytes(),
            ),
            placement,
        )?;
        self.by_job.insert(
            job_id,
            (
                id,
                PlanReservation {
                    parts: reservation.parts.clone(),
                },
            ),
        );
        Ok(())
    }

    /// Release a completed job's reservation; free space afterwards is
    /// byte-identical to the job never having been resident. Releasing a
    /// job that is not resident is a structured error — the simulator
    /// treats it as fatal (a double release would silently corrupt
    /// capacity accounting).
    pub fn release(&mut self, job_id: u64, gpus: usize) -> Result<(), String> {
        self.release_memory(job_id)?;
        self.release_gpus(gpus);
        Ok(())
    }

    /// Release only the memory half of a residency (first step of an
    /// evacuation), returning the reservation that was committed.
    pub fn release_memory(&mut self, job_id: u64) -> Result<PlanReservation, String> {
        let (rid, res) = self
            .by_job
            .remove(&job_id)
            .ok_or_else(|| format!("release of job {job_id}, which is not resident"))?;
        let released = self.alloc.release_strict(rid).map(|_| ());
        debug_assert!(released.is_ok(), "resident job must hold a live region");
        released.map_err(|e| format!("job {job_id}: {e}"))?;
        Ok(res)
    }

    /// Return `gpus` slots to the pool (completion, kill, or the
    /// checkpoint-restart fallback after an evacuation found no fit).
    pub fn release_gpus(&mut self, gpus: usize) {
        debug_assert!(self.gpus_in_use >= gpus, "GPU accounting underflow");
        self.gpus_in_use -= gpus;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::dev_tiny;
    use crate::topology::NodeId;
    use crate::util::units::GIB;

    fn res(parts: Vec<(NodeId, u64)>) -> PlanReservation {
        PlanReservation { parts }
    }

    #[test]
    fn reserve_release_round_trip_restores_free_and_gpus() {
        let topo = dev_tiny();
        let mut h = FleetHost::new(&topo);
        let before = h.free();
        assert_eq!(h.free_gpus(), 2);
        h.reserve(7, &res(vec![(NodeId(0), 2 * GIB), (NodeId(1), GIB)]), 1)
            .unwrap();
        assert_eq!(h.n_resident(), 1);
        assert_eq!(h.free_gpus(), 1);
        assert_eq!(h.free()[0], before[0] - 2 * GIB);
        assert_eq!(h.free()[1], before[1] - GIB);
        h.release(7, 1).unwrap();
        assert_eq!(h.free(), before, "free space byte-identical after release");
        assert_eq!(h.free_gpus(), 2);
    }

    #[test]
    fn releasing_a_non_resident_job_is_a_structured_error() {
        let topo = dev_tiny();
        let mut h = FleetHost::new(&topo);
        h.reserve(7, &res(vec![(NodeId(0), GIB)]), 1).unwrap();
        h.release(7, 1).unwrap();
        // Regression (the old API returned an ignorable bool): a double
        // release must surface as an error naming the job, with state
        // untouched.
        let err = h.release(7, 1).unwrap_err();
        assert!(err.contains("job 7") && err.contains("not resident"), "{err}");
        let err = h.release_memory(99).unwrap_err();
        assert!(err.contains("job 99"), "{err}");
        assert_eq!(h.free_gpus(), 2);
        assert_eq!(h.n_resident(), 0);
    }

    #[test]
    fn free_view_tracks_occupancy_down_to_zero() {
        let topo = dev_tiny();
        let mut h = FleetHost::new(&topo);
        h.reserve(1, &res(vec![(NodeId(1), 4 * GIB)]), 0).unwrap();
        let view = h.free_view();
        assert_eq!(view.mem_nodes[1].capacity, 0, "cxl0 fully occupied");
        assert_eq!(view.mem_nodes[0].capacity, topo.mem_nodes[0].capacity);
        assert_eq!(view.gpus.len(), topo.gpus.len());
    }

    #[test]
    fn overcommit_is_rejected_and_leaves_state_unchanged() {
        let topo = dev_tiny(); // 8 GiB DRAM
        let mut h = FleetHost::new(&topo);
        let before = h.free();
        let err = h
            .reserve(3, &res(vec![(NodeId(0), 100 * GIB)]), 1)
            .unwrap_err();
        assert!(err.shortfall > 0);
        assert_eq!(h.free(), before);
        assert_eq!(h.n_resident(), 0);
        assert_eq!(h.free_gpus(), 2, "failed reserve must not leak GPU slots");
    }

    #[test]
    fn set_capacity_degrades_free_without_touching_committed_bytes() {
        let topo = dev_tiny(); // cxl0 = 4 GiB
        let mut h = FleetHost::new(&topo);
        h.reserve(1, &res(vec![(NodeId(1), 3 * GIB)]), 0).unwrap();
        // Hot-remove: effective capacity 0 → free 0 (no underflow), used
        // bytes still reported so the simulator can pick victims.
        h.set_capacity(1, 0);
        assert_eq!(h.free()[1], 0);
        assert_eq!(h.used()[1], 3 * GIB);
        assert_eq!(h.free_view().mem_nodes[1].capacity, 0);
        assert_eq!(h.residents_on(1), vec![(1, 3 * GIB)]);
        // Restore: full capacity minus the still-committed bytes.
        h.set_capacity(1, 4 * GIB);
        assert_eq!(h.free()[1], GIB);
        // Squeeze below the committed bytes → free saturates at zero.
        h.set_capacity(1, 2 * GIB);
        assert_eq!(h.free()[1], 0);
        assert_eq!(h.used()[1], 3 * GIB, "overshoot is visible, not hidden");
    }

    #[test]
    fn evacuation_split_moves_memory_while_gpus_stay_held() {
        let topo = dev_tiny();
        let mut h = FleetHost::new(&topo);
        let pristine = h.free();
        h.reserve(5, &res(vec![(NodeId(1), 2 * GIB)]), 1).unwrap();
        assert_eq!(h.free_gpus(), 1);
        // Evacuate: release memory only, re-commit elsewhere.
        let old = h.release_memory(5).unwrap();
        assert_eq!(old.bytes_on(NodeId(1)), 2 * GIB);
        assert_eq!(h.free_gpus(), 1, "GPUs stay held through the move");
        h.reserve_memory(5, &res(vec![(NodeId(0), 2 * GIB), (NodeId(2), GIB)]))
            .unwrap();
        assert_eq!(h.reservation(5).unwrap().bytes_on(NodeId(0)), 2 * GIB);
        assert_eq!(h.residents_on(1), vec![]);
        assert_eq!(h.residents_on(2), vec![(5, GIB)]);
        // Full release restores the pristine free vector byte-identically.
        h.release(5, 1).unwrap();
        assert_eq!(h.free(), pristine);
        assert_eq!(h.free_gpus(), 2);
    }
}
