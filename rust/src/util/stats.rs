//! Small statistics toolkit used by the simulator, bench harness and reports.

/// Streaming mean/variance (Welford). O(1) memory.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile over a stored sample (fine for bench-sized data).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    xs: Vec<f64>,
    sorted: bool,
}

impl Sample {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if self.xs.len() == 1 {
            return self.xs[0];
        }
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(f64::NAN)
    }

    pub fn stddev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }
}

/// Fixed-bucket histogram over a linear range, with saturating outer buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            let idx = idx.min(self.buckets.len() - 1);
            self.buckets[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bucket_counts(&self) -> &[u64] {
        &self.buckets
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }
    pub fn overflow(&self) -> u64 {
        self.overflow
    }
}

/// Geometric-mean accumulator (used for normalized-throughput summaries, the
/// way the paper aggregates "x% of baseline" across a (C, B) grid).
#[derive(Clone, Debug, Default)]
pub struct GeoMean {
    log_sum: f64,
    n: u64,
}

impl GeoMean {
    pub fn push(&mut self, x: f64) {
        assert!(x > 0.0, "geomean of non-positive value {x}");
        self.log_sum += x.ln();
        self.n += 1;
    }
    pub fn value(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            (self.log_sum / self.n as f64).exp()
        }
    }
    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of that set is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_merge_equals_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn percentiles() {
        let mut s = Sample::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn percentile_single_element() {
        let mut s = Sample::new();
        s.push(3.25);
        assert_eq!(s.percentile(75.0), 3.25);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(42.0);
        assert_eq!(h.total(), 12);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert!(h.bucket_counts().iter().all(|&c| c == 1));
    }

    #[test]
    fn geomean() {
        let mut g = GeoMean::default();
        g.push(1.0);
        g.push(4.0);
        assert!((g.value() - 2.0).abs() < 1e-12);
    }
}
