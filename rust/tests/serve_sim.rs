//! Serving-simulator acceptance pins and invariants (ISSUE 10).
//!
//! * The tiering acceptance gate: a pinned long-context trace whose
//!   requests overflow the DRAM KV budget but fit DRAM+CXL — `dram-only`
//!   rejects every one, `tiered` completes them all, so the tiered cache
//!   sustains strictly more req/s while still meeting every TTFT SLO.
//! * Determinism: bit-identical result digests across reruns and
//!   `--threads` settings, on both the pinned and generated traces.
//! * proptest_lite invariants over random traces × policies: page
//!   conservation (allocated = freed + evicted, zero resident after
//!   drain), per-tier KV occupancy never exceeds its capacity in any
//!   sample, and every request reaches a terminal state.

use cxlfine::model::presets as mpresets;
use cxlfine::offload::schedules::inference::kv_bytes_per_token;
use cxlfine::serve::{
    admission_by_name, dram_kv_budget, kv, simulate_serving, RequestGen, RequestSpec,
    RequestTrace, ServeResult, PAGE_TOKENS,
};
use cxlfine::topology::presets::{dev_tiny, with_dram_capacity};
use cxlfine::topology::{MemKind, SystemTopology};
use cxlfine::util::units::MIB;

fn tiny_topo(dram: u64) -> SystemTopology {
    with_dram_capacity(dev_tiny(), dram)
}

fn run(
    topo: &SystemTopology,
    trace: &RequestTrace,
    kv_name: &str,
    adm: &str,
    threads: usize,
) -> ServeResult {
    simulate_serving(
        topo,
        trace,
        &kv::by_name(kv_name).unwrap(),
        &admission_by_name(adm).unwrap(),
        4,
        threads,
    )
}

/// The pinned gate trace: every prompt lands in the capacity gap —
/// bigger than the DRAM KV budget, far below DRAM+CXL.
fn gap_trace(topo: &SystemTopology, n: usize) -> RequestTrace {
    let budget = dram_kv_budget(topo, "tiny-2m");
    let m = mpresets::by_name("tiny-2m").unwrap();
    let page = PAGE_TOKENS as u64 * kv_bytes_per_token(&m);
    let dram_pages = budget / page;
    let prompt = (dram_pages as usize + 8) * PAGE_TOKENS;
    RequestTrace {
        seed: 0,
        requests: (0..n)
            .map(|i| RequestSpec {
                id: i as u64,
                arrival_s: i as f64,
                model: "tiny-2m".into(),
                prompt_tokens: prompt,
                max_output_tokens: 8,
                slo_ms: 3_600_000.0,
            })
            .collect(),
    }
}

/// The acceptance gate: on the pinned long-context trace the tiered KV
/// cache sustains strictly more req/s than dram-only at the same (met)
/// SLO — dram-only cannot hold a single request, tiered holds them all
/// by striping the cold pages across the AICs.
#[test]
fn tiered_kv_beats_dram_only_on_the_pinned_trace() {
    let topo = tiny_topo(48 * MIB);
    let trace = gap_trace(&topo, 6);
    let dram = run(&topo, &trace, "dram-only", "fcfs", 1);
    let tiered = run(&topo, &trace, "tiered", "fcfs", 1);

    assert_eq!(dram.rejected(), 6, "dram-only must reject the whole gap");
    assert_eq!(dram.completed(), 0);
    assert_eq!(tiered.rejected(), 0, "tiered must admit the whole gap");
    assert_eq!(tiered.completed(), 6);
    assert!(
        tiered.sustained_req_per_s() > dram.sustained_req_per_s(),
        "the strict req/s beat: {} vs {}",
        tiered.sustained_req_per_s(),
        dram.sustained_req_per_s()
    );
    // "At fixed p99": every completion still met its TTFT SLO.
    let p99 = tiered.p99_ttft_ms().unwrap();
    assert!(
        p99 <= trace.requests[0].slo_ms,
        "tiered p99 TTFT {p99}ms blew the {}ms SLO",
        trace.requests[0].slo_ms
    );
    assert_eq!(tiered.slo_attainment(), 1.0);
    // The beat came from tiering, not accounting tricks: cold pages
    // really were demoted and really were read back during decode.
    assert!(tiered.kv.demoted_bytes > 0);
    assert!(tiered.cold_read_bytes() > 0);
}

#[test]
fn serve_digests_survive_reruns_and_thread_counts() {
    let topo = tiny_topo(48 * MIB);
    let pinned = gap_trace(&topo, 4);
    let generated = RequestGen::mixed(77, 16, "tiny-2m").generate();
    for trace in [&pinned, &generated] {
        for kv_name in ["tiered:2", "dram-only"] {
            let a = run(&topo, trace, kv_name, "slo-strict", 1);
            let b = run(&topo, trace, kv_name, "slo-strict", 1);
            let c = run(&topo, trace, kv_name, "slo-strict", 4);
            assert_eq!(a.digest(), b.digest(), "{kv_name}: rerun must be bit-identical");
            assert_eq!(a.digest(), c.digest(), "{kv_name}: thread count must not leak");
            assert_eq!(a.n_events, c.n_events);
        }
    }
}

fn check_invariants(
    res: &ServeResult,
    topo: &SystemTopology,
    arrived: usize,
) -> Result<(), String> {
    // Conservation of requests: every arrival reaches a terminal state.
    if res.arrived() != arrived {
        return Err(format!("arrived {} != {arrived}", res.arrived()));
    }
    if res.completed() + res.rejected() + res.shed() != arrived || res.unfinished() != 0 {
        return Err(format!(
            "conservation broken: {} completed + {} rejected + {} shed != {arrived} \
             ({} unfinished)",
            res.completed(),
            res.rejected(),
            res.shed(),
            res.unfinished()
        ));
    }
    // Page conservation: the pager drained, and every allocated page was
    // handed back through exactly one of free / evict.
    if res.kv.resident_pages() != 0 {
        return Err(format!("{} pages resident after drain", res.kv.resident_pages()));
    }
    if res.kv.allocated_pages != res.kv.freed_pages + res.kv.evicted_pages {
        return Err(format!(
            "page ledger broken: {} allocated != {} freed + {} evicted",
            res.kv.allocated_pages, res.kv.freed_pages, res.kv.evicted_pages
        ));
    }
    // Per-tier occupancy: DRAM KV stays within its budget and every CXL
    // node within its capacity, in every sample; the curve ends at zero.
    for s in &res.samples {
        if s.used[0] > res.dram_kv_budget {
            return Err(format!(
                "DRAM KV {} over budget {} at t={}",
                s.used[0], res.dram_kv_budget, s.t_s
            ));
        }
        for (n, &u) in s.used.iter().enumerate().skip(1) {
            if topo.mem_nodes[n].kind == MemKind::CxlAic && u > topo.mem_nodes[n].capacity {
                return Err(format!("node {n} over capacity at t={}", s.t_s));
            }
        }
        if s.queue_len > arrived {
            return Err("queue longer than the population".into());
        }
    }
    if let Some(last) = res.samples.last() {
        if last.used.iter().any(|&u| u > 0) {
            return Err("occupancy curve does not end empty".into());
        }
    }
    // Per-request sanity: completions carry ordered timestamps, rejected
    // and shed requests never ran.
    for r in &res.records {
        match r.status {
            cxlfine::serve::RequestStatus::Completed => {
                let start = r.start_s.ok_or("completed without start")?;
                let first = r.first_token_s.ok_or("completed without first token")?;
                let finish = r.finish_s.ok_or("completed without finish")?;
                if !(r.arrival_s <= start && start < first && first <= finish) {
                    return Err(format!("request {} timestamps out of order", r.id));
                }
                if r.output_tokens == 0 {
                    return Err(format!("request {} completed with no output", r.id));
                }
                if !r.truncated && r.output_tokens as usize != r.max_output_tokens {
                    return Err(format!("request {} stopped early untruncated", r.id));
                }
            }
            _ => {
                if r.start_s.is_some() || r.finish_s.is_some() {
                    return Err(format!("non-completed request {} has run timestamps", r.id));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn serve_invariants_hold_over_random_traces() {
    use cxlfine::util::proptest_lite::*;
    // Tight enough that hot windows contend for DRAM and long prompts
    // spill (or, for dram-only, get rejected).
    let topo = tiny_topo(16 * MIB);
    let cases = PairOf(U64Range { lo: 1, hi: 1 << 40 }, UsizeRange { lo: 1, hi: 18 });
    forall("serve-invariants", 131, 5, &cases, |(seed, n)| {
        let mut gen = RequestGen::mixed(*seed, *n, "tiny-2m");
        gen.mean_interarrival_s = 0.05; // bursty: force queueing
        let trace = gen.generate();
        for kv_name in ["tiered", "tiered:2", "dram-only"] {
            for adm in ["fcfs", "slo-strict"] {
                let res = run(&topo, &trace, kv_name, adm, 2);
                check_invariants(&res, &topo, *n)
                    .map_err(|e| format!("{kv_name}+{adm} seed {seed}: {e}"))?;
            }
        }
        Ok(())
    });
}

/// A mixed pinned trace — short requests both policies serve plus one
/// long-context request only the tiered cache can hold: dram-only keeps
/// serving the shorts (it is not degenerately dead), yet the tiered
/// cache strictly completes more of the same trace.
#[test]
fn mixed_trace_tiers_rescue_the_long_request() {
    let topo = tiny_topo(16 * MIB);
    let budget = dram_kv_budget(&topo, "tiny-2m");
    let m = mpresets::by_name("tiny-2m").unwrap();
    let page = PAGE_TOKENS as u64 * kv_bytes_per_token(&m);
    let dram_pages = budget / page;
    assert!(dram_pages >= 8, "budget arithmetic drifted; retune the topology");
    let mut requests: Vec<RequestSpec> = (0..3)
        .map(|i| RequestSpec {
            id: i as u64,
            arrival_s: i as f64,
            model: "tiny-2m".into(),
            prompt_tokens: 4 * PAGE_TOKENS,
            max_output_tokens: 8,
            slo_ms: 3_600_000.0,
        })
        .collect();
    requests.push(RequestSpec {
        id: 3,
        arrival_s: 3.0,
        model: "tiny-2m".into(),
        prompt_tokens: (dram_pages as usize + 5) * PAGE_TOKENS,
        max_output_tokens: 8,
        slo_ms: 3_600_000.0,
    });
    let trace = RequestTrace { seed: 0, requests };
    let dram = run(&topo, &trace, "dram-only", "fcfs", 1);
    assert_eq!(dram.completed(), 3, "the short requests must still be served");
    assert_eq!(dram.rejected(), 1, "the long request cannot fit DRAM alone");
    let tiered = run(&topo, &trace, "tiered", "fcfs", 1);
    assert_eq!(tiered.completed(), 4);
    assert!(tiered.kv.demoted_bytes > 0, "the long prompt must spill to CXL");
    assert!(
        tiered.completed() > dram.completed(),
        "tiering must complete more of the same trace"
    );
}
