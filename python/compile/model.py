"""L2: the JAX transformer, written per-block so the Rust coordinator can
stream it (ZeRO-Offload granularity).

Entry points (each lowered separately by ``aot.py``; flattened-leaf order is
the contract with ``rust/src/train/``):

* ``embed_fwd(ids[B,C] i32, emb[V,H])            -> (x[B,C,H],)``
* ``block_fwd(x, ln1, wq, wk, wv, wo, ln2, wg, wu, wd) -> (y,)``
* ``block_bwd(x, <params>, dy)                   -> (dx, d<params>...)``
* ``head_loss(x, lnf, emb, labels)               -> (loss, dx, dlnf, demb)``
* ``embed_bwd(ids, dx)                           -> (demb,)``

``block_bwd`` is the whole-block VJP lowered as ONE computation taking the
*checkpointed input* — gradient checkpointing is therefore structural: the
artifact recomputes the forward from the checkpoint inside itself, exactly
like Fig. 1 step (5).

Attention runs through the L1 Pallas flash kernel; the loss through the L1
fused linear-cross-entropy kernel. RoPE provides positional information.
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.fused_ce import fused_linear_cross_entropy


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    """Architecture of the artifact model (CPU-PJRT sized)."""

    layers: int = 4
    hidden: int = 256
    heads: int = 4
    vocab: int = 2048
    ffn: int = 704
    batch: int = 4
    context: int = 128

    @property
    def head_dim(self):
        return self.hidden // self.heads

    def n_params(self):
        per_block = (
            2 * self.hidden  # two norms
            + 4 * self.hidden * self.hidden  # q, k, v, o
            + 3 * self.hidden * self.ffn  # gate, up, down
        )
        return self.layers * per_block + self.vocab * self.hidden + self.hidden


# Parameter leaf order for one block — the Rust side mirrors this.
BLOCK_PARAM_NAMES = ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "wu", "wd")


def block_param_shapes(cfg: TinyConfig):
    h, f = cfg.hidden, cfg.ffn
    return {
        "ln1": (h,),
        "wq": (h, h),
        "wk": (h, h),
        "wv": (h, h),
        "wo": (h, h),
        "ln2": (h,),
        "wg": (h, f),
        "wu": (h, f),
        "wd": (f, h),
    }


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope(x):
    """Rotary position embedding over ``[B, Hh, C, D]``."""
    _, _, c, d = x.shape
    half = d // 2
    pos = jnp.arange(c, dtype=jnp.float32)[:, None]
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]  # [C, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def block_fwd(cfg: TinyConfig, x, ln1, wq, wk, wv, wo, ln2, wg, wu, wd):
    """One pre-norm transformer block. x: [B, C, H] → [B, C, H]."""
    b, c, h = x.shape
    hh, d = cfg.heads, cfg.head_dim

    xn = rmsnorm(x, ln1)
    q = (xn @ wq).reshape(b, c, hh, d).transpose(0, 2, 1, 3)
    k = (xn @ wk).reshape(b, c, hh, d).transpose(0, 2, 1, 3)
    v = (xn @ wv).reshape(b, c, hh, d).transpose(0, 2, 1, 3)
    q, k = rope(q), rope(k)
    # fold batch+heads for the kernel
    attn = flash_attention(
        q.reshape(b * hh, c, d), k.reshape(b * hh, c, d), v.reshape(b * hh, c, d)
    )
    attn = attn.reshape(b, hh, c, d).transpose(0, 2, 1, 3).reshape(b, c, h)
    x = x + attn @ wo

    xn = rmsnorm(x, ln2)
    x = x + (jax.nn.silu(xn @ wg) * (xn @ wu)) @ wd
    return x


def block_bwd(cfg: TinyConfig, x, *params_and_dy):
    """Whole-block VJP from the checkpointed input (recompute included)."""
    *params, dy = params_and_dy
    _, vjp = jax.vjp(lambda x, *p: block_fwd(cfg, x, *p), x, *params)
    grads = vjp(dy)
    return tuple(grads)  # (dx, dln1, dwq, ..., dwd)


def embed_fwd(cfg: TinyConfig, ids, emb):
    return (jnp.take(emb, ids, axis=0),)


def embed_bwd(cfg: TinyConfig, ids, dx):
    demb = jnp.zeros((cfg.vocab, cfg.hidden), dx.dtype)
    return (demb.at[ids.reshape(-1)].add(dx.reshape(-1, cfg.hidden)),)


def head_loss(cfg: TinyConfig, x, lnf, emb, labels):
    """Final norm + tied head + fused CE; returns loss and input grads."""

    def loss_fn(x, lnf, emb):
        xn = rmsnorm(x, lnf).reshape(-1, cfg.hidden)
        return fused_linear_cross_entropy(xn, emb, labels.reshape(-1))

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(x, lnf, emb)
    dx, dlnf, demb = grads
    return loss, dx, dlnf, demb


def full_model_loss(cfg: TinyConfig, ids, labels, emb, lnf, blocks):
    """Reference whole-model loss (used by tests to validate the streamed
    per-block path end to end). ``blocks`` is a list of param dicts."""
    (x,) = embed_fwd(cfg, ids, emb)
    for p in blocks:
        x = block_fwd(cfg, x, *[p[n] for n in BLOCK_PARAM_NAMES])
    loss, *_ = head_loss(cfg, x, lnf, emb, labels)
    return loss
