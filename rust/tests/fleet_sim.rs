//! Fleet-simulator acceptance pins and invariants (ISSUE 5).
//!
//! * The static-OOM / lifetime-admit regression: a trace that `fifo`
//!   (static accounting) rejects but `placement-aware` (per-phase-peak
//!   accounting) completes.
//! * The pinned 100-job mixed-context trace where `placement-aware`
//!   strictly beats `fifo` on rejected-job count and does not lose on
//!   aggregate tokens/sec.
//! * Determinism: bit-identical result digests across reruns and
//!   `--threads` settings.
//! * proptest_lite invariants over random traces: per-node occupancy
//!   never exceeds capacity in any sample, every admitted job completes,
//!   completion respects readiness, conservation of jobs, bit-stable
//!   reruns across seeds × policies.

use cxlfine::fleet::{
    mixed_trace_with_xl, scheduler, simulate_fleet, FleetResult, FleetTrace, JobSpec, JobStatus,
    TraceGen,
};
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::qwen25_7b;
use cxlfine::topology::presets::{config_a, dev_tiny, with_dram_capacity};
use cxlfine::topology::SystemTopology;
use cxlfine::util::units::{GIB, MIB};

/// The acceptance regression: a job whose static footprint overflows DRAM
/// but whose per-phase peak fits. Fifo (static accounting) OOM-rejects it;
/// placement-aware admits it under lifetime accounting — with the very
/// engine the job requested.
#[test]
fn lifetime_admission_rescues_a_static_oom_job() {
    let model = qwen25_7b();
    let w = Workload::new(1, 8, 4096);
    let f = Footprint::compute(&model, &w);
    // Per-phase peaks of the zero-offload liveness windows (same
    // arithmetic as `lifetime_accounting_fits_cell_static_rejects`).
    let peak_bwd = f.params_bf16 + f.grads_bf16 + f.activations_bf16;
    let peak_step =
        f.params_fp32 + f.grads_fp32 + f.optimizer_fp32 + f.params_bf16 + f.grads_bf16;
    let peak = peak_bwd.max(peak_step);
    let total = f.total();
    assert!(peak < total);
    // DRAM budget strictly between the peak and the static sum.
    let topo = with_dram_capacity(config_a(), peak + (total - peak) / 2);
    let trace = FleetTrace {
        seed: 0,
        jobs: vec![JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: "7b".into(),
            gpus: 1,
            batch: 8,
            context: 4096,
            schedule: "zero-offload".into(),
            engine: "baseline-dram".into(),
            iterations: 2,
        }],
    };
    let fifo = scheduler::by_name("fifo").unwrap();
    let backfill = scheduler::by_name("backfill").unwrap();
    let pa = scheduler::by_name("placement-aware").unwrap();
    for static_policy in [&fifo, &backfill] {
        let r = simulate_fleet(&topo, &trace, static_policy, 1);
        assert_eq!(r.rejected(), 1, "{}: static accounting must OOM-reject", r.policy);
        assert_eq!(r.completed(), 0);
        assert!(r.records[0].start_s.is_none());
    }
    let r = simulate_fleet(&topo, &trace, &pa, 1);
    assert_eq!(r.rejected(), 0);
    assert_eq!(r.completed(), 1, "per-phase peak accounting must admit the job");
    assert_eq!(
        r.records[0].engine_used.as_deref(),
        Some("baseline-dram"),
        "the requested engine suffices once the accounting is lifetime-aware"
    );
    assert!(r.records[0].jct_s().unwrap() > 0.0);
}

/// The pinned 100-job mixed-context trace: 92 mixed jobs plus 8 XL jobs
/// in the static/lifetime gap. placement-aware strictly beats fifo on
/// rejected-job count and is no worse on aggregate tokens/sec; digests
/// are bit-identical across reruns and thread counts.
#[test]
fn pinned_100_job_trace_placement_aware_beats_fifo() {
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let trace = mixed_trace_with_xl(&topo, 1007, 92, 8);
    assert_eq!(
        trace.jobs.len(),
        100,
        "the XL static/lifetime gap cell must exist at a 128 GiB DRAM budget"
    );
    let fifo = scheduler::by_name("fifo").unwrap();
    let pa = scheduler::by_name("placement-aware").unwrap();
    let rf = simulate_fleet(&topo, &trace, &fifo, 4);
    let rp = simulate_fleet(&topo, &trace, &pa, 4);
    assert_eq!(rf.rejected(), 8, "fifo must reject exactly the XL jobs");
    assert_eq!(rf.completed(), 92);
    assert_eq!(rp.rejected(), 0, "placement-aware must admit the whole trace");
    assert_eq!(rp.completed(), 100);
    assert!(
        rf.rejected() > rp.rejected(),
        "the strict beat on rejected-job count"
    );
    let (af, ap) = (rf.aggregate_tokens_per_sec(), rp.aggregate_tokens_per_sec());
    assert!(
        ap + 1e-9 >= af,
        "placement-aware must not lose aggregate throughput: {ap:.1} vs {af:.1} tok/s"
    );
    // Every XL job ran under lifetime accounting with its requested engine.
    for r in rp.records.iter().filter(|r| r.id >= 92) {
        assert_eq!(r.status, JobStatus::Completed);
        assert_eq!(r.engine_used.as_deref(), Some("cxl-aware+striping"));
    }
    // Determinism: rerun and thread-count invariance, bit for bit.
    assert_eq!(rf.digest(), simulate_fleet(&topo, &trace, &fifo, 1).digest());
    assert_eq!(rp.digest(), simulate_fleet(&topo, &trace, &pa, 1).digest());
}

/// dev-tiny shrunk so tiny-2m jobs contend for both memory and GPU slots.
fn tight_topo() -> SystemTopology {
    let mut t = dev_tiny();
    t.mem_nodes[0].capacity = 48 * MIB;
    t.mem_nodes[1].capacity = 16 * MIB;
    t.mem_nodes[2].capacity = 16 * MIB;
    t.validate();
    t
}

fn tiny_trace(seed: u64, n_jobs: usize) -> FleetTrace {
    let mut g = TraceGen::mixed(seed, n_jobs);
    g.models = vec!["tiny-2m".into()];
    g.contexts = vec![256, 1024, 16384];
    g.batches = vec![1, 2, 8];
    g.schedules = vec!["zero-offload".into(), "lora:4".into()];
    g.engines = vec!["cxl-aware+striping".into(), "baseline-dram".into()];
    // Tiny-model iterations simulate in milliseconds, so arrivals must be
    // near-simultaneous for the queue to ever be non-trivial.
    g.mean_interarrival_s = 0.001;
    g.min_iterations = 1;
    g.max_iterations = 3;
    g.generate()
}

fn check_invariants(res: &FleetResult, topo: &SystemTopology, arrived: usize) -> Result<(), String> {
    // Conservation: every arrived job is terminal.
    if res.arrived() != arrived {
        return Err(format!("arrived {} != {arrived}", res.arrived()));
    }
    if res.completed() + res.rejected() != arrived || res.unfinished() != 0 {
        return Err(format!(
            "conservation broken: {} completed + {} rejected != {arrived} ({} unfinished)",
            res.completed(),
            res.rejected(),
            res.unfinished()
        ));
    }
    // Occupancy never exceeds any node's capacity; running never exceeds
    // the GPU count; queues never exceed the population.
    for s in &res.samples {
        for (n, &u) in s.used.iter().enumerate() {
            if u > topo.mem_nodes[n].capacity {
                return Err(format!("node {n} over capacity at t={}", s.t_s));
            }
        }
        if s.running > topo.gpus.len() {
            return Err(format!("{} running on {} GPUs", s.running, topo.gpus.len()));
        }
        if s.queue_len > arrived {
            return Err("queue longer than the population".into());
        }
    }
    // Per-job readiness: starts after arrival, finishes exactly
    // iterations × iter_s later; rejected jobs never ran.
    for r in &res.records {
        match r.status {
            JobStatus::Completed => {
                let (start, finish, iter_s) = (
                    r.start_s.ok_or("completed without start")?,
                    r.finish_s.ok_or("completed without finish")?,
                    r.iter_s.ok_or("completed without iter_s")?,
                );
                if start < r.arrival_s {
                    return Err(format!("job {} started before it arrived", r.id));
                }
                let expect = start + iter_s * r.iterations as f64;
                if (finish - expect).abs() > 1e-9 * expect.max(1.0) {
                    return Err(format!("job {} finish {finish} != start+run {expect}", r.id));
                }
                if r.engine_used.is_none() {
                    return Err(format!("job {} completed without an engine", r.id));
                }
            }
            JobStatus::Rejected => {
                if r.start_s.is_some() || r.finish_s.is_some() {
                    return Err(format!("rejected job {} has run timestamps", r.id));
                }
            }
            other => return Err(format!("job {} left in state {:?}", r.id, other)),
        }
    }
    Ok(())
}

#[test]
fn fleet_invariants_hold_over_random_traces() {
    use cxlfine::util::proptest_lite::*;
    let topo = tight_topo();
    let cases = PairOf(U64Range { lo: 1, hi: 1 << 40 }, UsizeRange { lo: 1, hi: 20 });
    forall("fleet-invariants", 97, 5, &cases, |(seed, n_jobs)| {
        let trace = tiny_trace(*seed, *n_jobs);
        for policy in scheduler::registry() {
            let res = simulate_fleet(&topo, &trace, &policy, 2);
            check_invariants(&res, &topo, *n_jobs)
                .map_err(|e| format!("{} seed {seed}: {e}", policy.name()))?;
        }
        Ok(())
    });
}

#[test]
fn fleet_reruns_are_bit_stable_across_seeds_and_policies() {
    let topo = tight_topo();
    for seed in [3u64, 19] {
        let trace = tiny_trace(seed, 14);
        for policy in scheduler::registry() {
            let a = simulate_fleet(&topo, &trace, &policy, 1);
            let b = simulate_fleet(&topo, &trace, &policy, 4);
            assert_eq!(
                a.digest(),
                b.digest(),
                "{} seed {seed}: digests must survive rerun + thread change",
                policy.name()
            );
            assert_eq!(a.n_events, b.n_events);
        }
    }
}

/// Sanity for the queueing dynamics the policies differ on: the bursty
/// tiny trace must actually exercise the queue (otherwise the invariant
/// suite proves nothing about scheduling).
#[test]
fn tiny_traces_actually_queue() {
    let topo = tight_topo();
    let trace = tiny_trace(5, 16);
    let fifo = scheduler::by_name("fifo").unwrap();
    let res = simulate_fleet(&topo, &trace, &fifo, 1);
    assert!(
        res.max_queue_len() >= 2,
        "burst must build a queue, got {}",
        res.max_queue_len()
    );
    assert!(res.completed() >= 1);
}
