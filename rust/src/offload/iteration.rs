//! **FROZEN differential oracle** — the pre-IR hand-woven iteration
//! engine, kept verbatim so `rust/tests/schedule_parity.rs` can assert
//! that the schedule-graph executor (`offload::schedule` +
//! `offload::executor`, the path behind [`crate::offload::
//! simulate_iteration`] since ISSUE 3) reproduces it **byte-for-byte** on
//! the paper's cells. Do not modify this file except to delete it once
//! the parity lock has outlived its usefulness; new behavior goes into
//! schedule builders.
//!
//! One training iteration of the Figure-1 workflow, simulated over the
//! fabric with full transfer/compute overlap.
//!
//! Per GPU, the schedule mirrors ZeRO-Offload with offloaded activation
//! checkpointing:
//!
//! * **FWD** — parameters stream block-by-block (prefetch depth `D`);
//!   after each block's kernel, its input activation checkpoint is
//!   offloaded to host memory asynchronously.
//! * **BWD** — blocks run in reverse; each needs its parameters *and* its
//!   activation checkpoint back on the GPU (gated on the checkpoint's
//!   offload having completed), runs recompute + backward, then offloads
//!   the block's bf16 gradients.
//! * **STEP** — after every GPU's last gradient lands in host memory, the
//!   CPU optimizer updates fp32 P/G/O in place (timed by the calibrated
//!   memory model) and casts fresh bf16 parameters for the next step.
//!
//! All byte counts come from the [`MemoryPlan`]'s regions, so the placement
//! policy shows up *only* through which nodes flows touch and where the
//! optimizer's working set lives — the same separation the real system has.

use super::metrics::PhaseBreakdown;
use super::plan::{MemoryPlan, RunConfig};
use crate::model::flops;
use crate::sim::fabric::{Dir, Fabric};
use crate::sim::flow::Event;
use crate::sim::memmodel::OptimizerMemModel;
use crate::topology::{GpuId, SystemTopology};

/// Event tags: kind · 2^48 | gpu · 2^32 | block.
///
/// Field widths: 16 bits of kind headroom, 16-bit GPU index, 32-bit block
/// index. The original packing (kind·2^24 | gpu·2^16 | block) silently
/// corrupted tags once `gpu > 255` (bled into the kind field) or
/// `block > 65535` (bled into the gpu field) — far below the GPU-fleet and
/// deep-model scales the roadmap targets. `tag` now debug-asserts both
/// bounds and the round-trip is regression-tested at the field boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    FwdParamLoad = 0,
    FwdCompute = 1,
    ActOffload = 2,
    BwdParamLoad = 3,
    ActLoad = 4,
    BwdCompute = 5,
    GradOffload = 6,
    Step = 7,
}

const TAG_GPU_BITS: u32 = 16;
const TAG_BLOCK_BITS: u32 = 32;

fn tag(kind: Kind, gpu: usize, block: usize) -> u64 {
    debug_assert!(
        (gpu as u64) < (1 << TAG_GPU_BITS),
        "gpu index {gpu} overflows the {TAG_GPU_BITS}-bit tag field"
    );
    debug_assert!(
        (block as u64) < (1u64 << TAG_BLOCK_BITS),
        "block index {block} overflows the {TAG_BLOCK_BITS}-bit tag field"
    );
    ((kind as u64) << (TAG_GPU_BITS + TAG_BLOCK_BITS))
        | ((gpu as u64) << TAG_BLOCK_BITS)
        | block as u64
}

fn untag(t: u64) -> (Kind, usize, usize) {
    let kind = match t >> (TAG_GPU_BITS + TAG_BLOCK_BITS) {
        0 => Kind::FwdParamLoad,
        1 => Kind::FwdCompute,
        2 => Kind::ActOffload,
        3 => Kind::BwdParamLoad,
        4 => Kind::ActLoad,
        5 => Kind::BwdCompute,
        6 => Kind::GradOffload,
        7 => Kind::Step,
        k => panic!("bad tag kind {k}"),
    };
    (
        kind,
        ((t >> TAG_BLOCK_BITS) & ((1 << TAG_GPU_BITS) - 1)) as usize,
        (t & ((1u64 << TAG_BLOCK_BITS) - 1)) as usize,
    )
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum GpuPhase {
    Fwd,
    Bwd,
    Done,
}

/// Per-GPU scheduler state.
struct GpuState {
    phase: GpuPhase,
    // FWD
    fwd_loaded: Vec<bool>,
    fwd_next_compute: usize,
    fwd_computing: bool,
    act_offloaded: Vec<bool>,
    // pending striped flows per logical transfer: remaining stripe count
    // keyed by (kind, block)
    // BWD
    bwd_param_loaded: Vec<bool>,
    bwd_act_loaded: Vec<bool>,
    bwd_act_requested: Vec<bool>,
    bwd_next_compute: isize,
    bwd_computing: bool,
    grads_pending: usize,
    fwd_end: Option<f64>,
    bwd_end: Option<f64>,
}

impl GpuState {
    fn new(layers: usize) -> Self {
        Self {
            phase: GpuPhase::Fwd,
            fwd_loaded: vec![false; layers],
            fwd_next_compute: 0,
            fwd_computing: false,
            act_offloaded: vec![false; layers],
            bwd_param_loaded: vec![false; layers],
            bwd_act_loaded: vec![false; layers],
            bwd_act_requested: vec![false; layers],
            bwd_next_compute: layers as isize - 1,
            bwd_computing: false,
            grads_pending: layers,
            fwd_end: None,
            bwd_end: None,
        }
    }
}

/// Stripe completion tracker: a logical transfer may be several flows.
#[derive(Default)]
struct StripeTracker {
    remaining: std::collections::HashMap<u64, usize>,
}

impl StripeTracker {
    fn expect(&mut self, tag: u64, n: usize) {
        assert!(n > 0);
        let prev = self.remaining.insert(tag, n);
        assert!(prev.is_none(), "duplicate logical transfer {tag}");
    }
    /// Returns true when the LAST stripe of the logical transfer lands.
    fn arrive(&mut self, tag: u64) -> bool {
        let r = self
            .remaining
            .get_mut(&tag)
            .unwrap_or_else(|| panic!("unexpected stripe completion {tag}"));
        *r -= 1;
        if *r == 0 {
            self.remaining.remove(&tag);
            true
        } else {
            false
        }
    }
}

/// Simulate one iteration on the FROZEN legacy engine; returns the phase
/// breakdown. Production callers use [`crate::offload::simulate_iteration`]
/// (the schedule-graph executor) — this remains only as the parity oracle.
pub fn legacy_simulate_iteration(
    topo: &SystemTopology,
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
) -> PhaseBreakdown {
    legacy_simulate_iteration_traced(topo, cfg, plan).0
}

fn span_label(kind: Kind, g: usize, l: usize) -> (String, String) {
    match kind {
        Kind::FwdParamLoad => (format!("param-load b{l}"), format!("gpu{g}/h2d")),
        Kind::FwdCompute => (format!("fwd b{l}"), format!("gpu{g}/compute")),
        Kind::ActOffload => (format!("ckpt-offload b{l}"), format!("gpu{g}/d2h")),
        Kind::BwdParamLoad => (format!("param-reload b{l}"), format!("gpu{g}/h2d")),
        Kind::ActLoad => (format!("ckpt-load b{l}"), format!("gpu{g}/h2d")),
        Kind::BwdCompute => (format!("bwd b{l}"), format!("gpu{g}/compute")),
        Kind::GradOffload => (format!("grad-offload b{l}"), format!("gpu{g}/d2h")),
        Kind::Step => ("optimizer step".into(), "cpu/step".into()),
    }
}

/// Simulate one iteration on the FROZEN legacy engine, additionally
/// recording a full execution trace (exportable as Chrome trace JSON via
/// `TraceRecorder::to_chrome_trace`).
pub fn legacy_simulate_iteration_traced(
    topo: &SystemTopology,
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
) -> (PhaseBreakdown, crate::sim::trace::TraceRecorder) {
    let n_gpus = cfg.workload.n_gpus;
    assert!(
        n_gpus <= topo.gpus.len(),
        "workload wants {n_gpus} GPUs, topology has {}",
        topo.gpus.len()
    );
    let layers = cfg.model.layers;
    let depth = cfg.prefetch_depth.max(1);
    let b = cfg.workload.batch;
    let c = cfg.workload.context;

    // Byte sizes per logical transfer.
    let param_block_bytes = plan.footprint.params_bf16 as f64 / layers as f64;
    let act_block_bytes =
        2.0 * (b as f64) * (c as f64) * (cfg.model.hidden as f64);
    let grad_block_bytes = plan.footprint.grads_bf16 as f64 / layers as f64;

    // GPU compute times.
    let gflops = topo.gpus[0].effective_flops();
    let t_fwd_block = flops::block_fwd_flops(&cfg.model, b, c) / gflops;
    let t_bwd_block = flops::block_bwd_flops(&cfg.model, b, c, true) / gflops;
    // embedding + head forward and backward, charged to first/last events
    let t_head = flops::head_fwd_flops(&cfg.model, b, c) / gflops;

    let p16 = plan.params16_fractions();
    let g16 = plan.grads16_fractions();
    let acts: Vec<_> = (0..n_gpus)
        .map(|g| plan.activation_fractions(GpuId(g)))
        .collect();

    let mut fab = Fabric::new(topo);
    let mut stripes = StripeTracker::default();
    let mut gpus: Vec<GpuState> = (0..n_gpus).map(|_| GpuState::new(layers)).collect();
    let mut trace = crate::sim::trace::TraceRecorder::new();
    // compute-timer start times (timers do not carry start info)
    let mut timer_start: std::collections::HashMap<u64, f64> = Default::default();

    // --- helpers -----------------------------------------------------
    macro_rules! load_params {
        ($fab:expr, $stripes:expr, $kind:expr, $g:expr, $l:expr) => {{
            let t = tag($kind, $g, $l);
            let flows =
                $fab.transfer_striped(GpuId($g), &p16, Dir::HostToGpu, param_block_bytes, t);
            $stripes.expect(t, flows.len());
        }};
    }

    // kick off: each GPU prefetches the first `depth` blocks' parameters
    for g in 0..n_gpus {
        for l in 0..depth.min(layers) {
            load_params!(fab, stripes, Kind::FwdParamLoad, g, l);
        }
    }

    let mut fwd_phase_end = 0.0f64;
    let mut bwd_phase_end = 0.0f64;
    let mut grads_done = 0usize;

    // --- event loop ---------------------------------------------------
    while let Some(ev) = fab.next_event() {
        let now = fab.now();
        let t = match ev {
            Event::FlowDone { id, tag } => {
                // record the flow's span (stripes become separate spans),
                // consuming the stats entry so the finished map stays empty
                // across arbitrarily long simulations (multi-epoch
                // `train::loop_` runs issue millions of flows)
                if let Some(st) = fab.take_stats(id) {
                    let (kind, g, l) = untag(tag);
                    let (name, lane) = span_label(kind, g, l);
                    trace.record(name, lane, st.issued, st.finished);
                }
                tag
            }
            Event::TimerFired { tag, .. } => {
                let (kind, g, l) = untag(tag);
                let (name, lane) = span_label(kind, g, l);
                let start = timer_start.remove(&tag).unwrap_or(now);
                trace.record(name, lane, start, now);
                tag
            }
        };
        let (kind, g, l) = untag(t);
        match kind {
            Kind::FwdParamLoad => {
                if !stripes.arrive(t) {
                    continue;
                }
                gpus[g].fwd_loaded[l] = true;
                try_start_fwd(&mut fab, &mut gpus[g], g, t_fwd_block, t_head, &mut timer_start);
            }
            Kind::FwdCompute => {
                let st = &mut gpus[g];
                st.fwd_computing = false;
                // offload this block's checkpoint
                let at = tag(Kind::ActOffload, g, l);
                let flows =
                    fab.transfer_striped(GpuId(g), &acts[g], Dir::GpuToHost, act_block_bytes, at);
                stripes.expect(at, flows.len());
                // prefetch a later block's params
                let nxt = l + depth;
                if nxt < layers {
                    load_params!(fab, stripes, Kind::FwdParamLoad, g, nxt);
                }
                st.fwd_next_compute += 1;
                if st.fwd_next_compute == layers {
                    st.phase = GpuPhase::Bwd;
                    st.fwd_end = Some(now);
                    fwd_phase_end = fwd_phase_end.max(now);
                    // start BWD prefetches (descending from the top block)
                    start_bwd_prefetch(&mut fab, &mut stripes, &mut gpus[g], g, layers, depth, &p16, param_block_bytes, &acts[g], act_block_bytes);
                } else {
                    try_start_fwd(&mut fab, &mut gpus[g], g, t_fwd_block, t_head, &mut timer_start);
                }
            }
            Kind::ActOffload => {
                if !stripes.arrive(t) {
                    continue;
                }
                gpus[g].act_offloaded[l] = true;
                // if BWD is waiting on this checkpoint, request it now
                if gpus[g].phase == GpuPhase::Bwd {
                    maybe_request_act(&mut fab, &mut stripes, &mut gpus[g], g, l, depth, &acts[g], act_block_bytes);
                    try_start_bwd(&mut fab, &mut gpus[g], g, t_bwd_block, t_head, &mut timer_start);
                }
            }
            Kind::BwdParamLoad => {
                if !stripes.arrive(t) {
                    continue;
                }
                gpus[g].bwd_param_loaded[l] = true;
                try_start_bwd(&mut fab, &mut gpus[g], g, t_bwd_block, t_head, &mut timer_start);
            }
            Kind::ActLoad => {
                if !stripes.arrive(t) {
                    continue;
                }
                gpus[g].bwd_act_loaded[l] = true;
                try_start_bwd(&mut fab, &mut gpus[g], g, t_bwd_block, t_head, &mut timer_start);
            }
            Kind::BwdCompute => {
                let st = &mut gpus[g];
                st.bwd_computing = false;
                // offload this block's gradients
                let gt = tag(Kind::GradOffload, g, l);
                let flows =
                    fab.transfer_striped(GpuId(g), &g16, Dir::GpuToHost, grad_block_bytes, gt);
                stripes.expect(gt, flows.len());
                st.bwd_next_compute -= 1;
                // prefetch params/acts `depth` below
                let nxt = l as isize - depth as isize;
                if nxt >= 0 {
                    let nxt = nxt as usize;
                    load_params!(fab, stripes, Kind::BwdParamLoad, g, nxt);
                    maybe_request_act(&mut fab, &mut stripes, &mut gpus[g], g, nxt, depth, &acts[g], act_block_bytes);
                }
                try_start_bwd(&mut fab, &mut gpus[g], g, t_bwd_block, t_head, &mut timer_start);
            }
            Kind::GradOffload => {
                if !stripes.arrive(t) {
                    continue;
                }
                let st = &mut gpus[g];
                st.grads_pending -= 1;
                if st.grads_pending == 0 {
                    st.phase = GpuPhase::Done;
                    st.bwd_end = Some(now);
                    grads_done += 1;
                    if grads_done == n_gpus {
                        bwd_phase_end = now;
                        // STEP: optimizer update + bf16 cast
                        let mm = OptimizerMemModel::new(topo);
                        let opt_layout = plan.opt_layout();
                        let t_step = mm.step_time(cfg.model.params(), &opt_layout);
                        // cast: read 4·P fp32 (master) + write 2·P bf16
                        let t_cast = mm.stream_time(
                            plan.footprint.params_fp32 as f64,
                            &plan.region_layout(plan.master),
                        ) + mm.stream_time(
                            plan.footprint.params_bf16 as f64,
                            &plan.region_layout(plan.params16),
                        );
                        let st_tag = tag(Kind::Step, 0, 0);
                        timer_start.insert(st_tag, fab.now());
                        fab.compute(t_step + t_cast, st_tag);
                    }
                }
            }
            Kind::Step => {
                let iter_s = fab.now();
                debug_assert_eq!(
                    fab.sim.finished_len(),
                    0,
                    "every completed flow's stats must have been consumed"
                );
                return (
                    PhaseBreakdown {
                        fwd_s: fwd_phase_end,
                        bwd_s: bwd_phase_end - fwd_phase_end,
                        step_s: iter_s - bwd_phase_end,
                        iter_s,
                        tokens: cfg.workload.tokens_per_iter(),
                    },
                    trace,
                );
            }
        }
    }
    panic!("simulation drained without completing the iteration");
}

fn try_start_fwd(
    fab: &mut Fabric,
    st: &mut GpuState,
    g: usize,
    t_block: f64,
    t_head: f64,
    timer_start: &mut std::collections::HashMap<u64, f64>,
) {
    if st.phase != GpuPhase::Fwd || st.fwd_computing {
        return;
    }
    let l = st.fwd_next_compute;
    if l < st.fwd_loaded.len() && st.fwd_loaded[l] {
        st.fwd_computing = true;
        // charge embedding on the first block, LM head + loss on the last
        let extra = if l == 0 || l == st.fwd_loaded.len() - 1 {
            t_head * 0.5
        } else {
            0.0
        };
        let t = tag(Kind::FwdCompute, g, l);
        timer_start.insert(t, fab.now());
        fab.compute(t_block + extra, t);
    }
}

#[allow(clippy::too_many_arguments)]
fn start_bwd_prefetch(
    fab: &mut Fabric,
    stripes: &mut StripeTracker,
    st: &mut GpuState,
    g: usize,
    layers: usize,
    depth: usize,
    p16: &[(crate::topology::NodeId, f64)],
    param_block_bytes: f64,
    acts: &[(crate::topology::NodeId, f64)],
    act_block_bytes: f64,
) {
    for k in 0..depth.min(layers) {
        let l = layers - 1 - k;
        let t = tag(Kind::BwdParamLoad, g, l);
        let flows = fab.transfer_striped(GpuId(g), p16, Dir::HostToGpu, param_block_bytes, t);
        stripes.expect(t, flows.len());
        maybe_request_act(fab, stripes, st, g, l, depth, acts, act_block_bytes);
    }
}

/// Request the activation checkpoint for block `l` if (a) it is within the
/// prefetch window, (b) its offload has completed, (c) not yet requested.
fn maybe_request_act(
    fab: &mut Fabric,
    stripes: &mut StripeTracker,
    st: &mut GpuState,
    g: usize,
    l: usize,
    _depth: usize,
    acts: &[(crate::topology::NodeId, f64)],
    act_block_bytes: f64,
) {
    if st.bwd_act_requested[l] || !st.act_offloaded[l] {
        return;
    }
    st.bwd_act_requested[l] = true;
    let t = tag(Kind::ActLoad, g, l);
    let flows = fab.transfer_striped(GpuId(g), acts, Dir::HostToGpu, act_block_bytes, t);
    stripes.expect(t, flows.len());
}

fn try_start_bwd(
    fab: &mut Fabric,
    st: &mut GpuState,
    g: usize,
    t_block: f64,
    t_head: f64,
    timer_start: &mut std::collections::HashMap<u64, f64>,
) {
    if st.phase != GpuPhase::Bwd || st.bwd_computing || st.bwd_next_compute < 0 {
        return;
    }
    let l = st.bwd_next_compute as usize;
    if st.bwd_param_loaded[l] && st.bwd_act_loaded[l] {
        st.bwd_computing = true;
        let extra = if l == st.bwd_param_loaded.len() - 1 {
            t_head // head backward ≈ 2× its fwd, recompute ≈ fwd; fold as 1×
        } else {
            0.0
        };
        let t = tag(Kind::BwdCompute, g, l);
        timer_start.insert(t, fab.now());
        fab.compute(t_block + extra, t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::footprint::Workload;
    use crate::model::presets::{mistral_nemo_12b, qwen25_7b, tiny_2m};
    use crate::topology::presets::{config_a, config_b, dev_tiny, with_dram_capacity};
    use crate::util::units::GIB;

    fn run(
        topo: &SystemTopology,
        model: crate::model::ModelConfig,
        w: Workload,
        policy: Policy,
    ) -> PhaseBreakdown {
        let cfg = RunConfig::new(model, w, policy);
        let plan = MemoryPlan::build(topo, &cfg).unwrap();
        legacy_simulate_iteration(topo, &cfg, &plan)
    }

    #[test]
    fn tag_roundtrips_at_field_boundaries() {
        // Regression for the old kind·2^24|gpu·2^16|block packing: gpu 256
        // used to collide with the kind field and block 65536 with the gpu
        // field. Every (kind, gpu, block) at and across the old boundaries
        // must round-trip exactly now.
        let kinds = [
            Kind::FwdParamLoad,
            Kind::FwdCompute,
            Kind::ActOffload,
            Kind::BwdParamLoad,
            Kind::ActLoad,
            Kind::BwdCompute,
            Kind::GradOffload,
            Kind::Step,
        ];
        let gpus = [0usize, 1, 255, 256, 65_535];
        let blocks = [0usize, 1, 65_535, 65_536, u32::MAX as usize];
        for &k in &kinds {
            for &g in &gpus {
                for &b in &blocks {
                    let t = tag(k, g, b);
                    assert_eq!(untag(t), (k, g, b), "tag {t:#x} mangled ({k:?}, {g}, {b})");
                }
            }
        }
        // distinctness across the old collision pairs
        assert_ne!(
            tag(Kind::FwdParamLoad, 256, 0),
            tag(Kind::FwdCompute, 0, 0),
            "gpu 256 must not alias the next kind"
        );
        assert_ne!(
            tag(Kind::FwdParamLoad, 0, 65_536),
            tag(Kind::FwdParamLoad, 1, 0),
            "block 65536 must not alias gpu 1"
        );
    }

    #[test]
    fn phases_are_positive_and_ordered() {
        let topo = config_a();
        let b = run(
            &topo,
            qwen25_7b(),
            Workload::new(1, 8, 4096),
            Policy::DramOnly,
        );
        assert!(b.fwd_s > 0.0 && b.bwd_s > 0.0 && b.step_s > 0.0);
        assert!((b.fwd_s + b.bwd_s + b.step_s - b.iter_s).abs() < 1e-9);
        // backward (3× compute) takes longer than forward
        assert!(b.bwd_s > b.fwd_s);
    }

    #[test]
    fn naive_cxl_slower_than_baseline_single_gpu() {
        // Fig. 9a: naive CXL → 76–94 % of baseline.
        let base_topo = config_a();
        let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
        let w = Workload::new(1, 8, 4096);
        let base = run(&base_topo, qwen25_7b(), w, Policy::DramOnly);
        let naive = run(&cxl_topo, qwen25_7b(), w, Policy::NaiveInterleave);
        let rel = base.iter_s / naive.iter_s;
        assert!(
            (0.70..0.97).contains(&rel),
            "naive relative throughput {rel} outside the paper's band"
        );
    }

    #[test]
    fn cxl_aware_recovers_most_of_the_loss() {
        // Fig. 9a: CXL-aware → 97–99 % of baseline (single GPU, 7B).
        let base_topo = config_a();
        let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
        let w = Workload::new(1, 8, 4096);
        let base = run(&base_topo, qwen25_7b(), w, Policy::DramOnly);
        let ours = run(
            &cxl_topo,
            qwen25_7b(),
            w,
            Policy::CxlAware { striping: false },
        );
        let naive = run(&cxl_topo, qwen25_7b(), w, Policy::NaiveInterleave);
        let rel = base.iter_s / ours.iter_s;
        assert!(rel > 0.94, "cxl-aware relative throughput {rel}");
        assert!(ours.iter_s < naive.iter_s, "ours must beat naive");
    }

    #[test]
    fn naive_step_phase_inflates_most_single_gpu() {
        // Fig. 7a: STEP suffers the most under naive placement.
        let base_topo = config_a();
        let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
        let w = Workload::new(1, 16, 4096);
        let base = run(&base_topo, mistral_nemo_12b(), w, Policy::DramOnly);
        let naive = run(&cxl_topo, mistral_nemo_12b(), w, Policy::NaiveInterleave);
        let step_ratio = naive.step_s / base.step_s;
        let fwd_ratio = naive.fwd_s / base.fwd_s;
        assert!(step_ratio > 1.5, "step inflation {step_ratio}");
        assert!(
            step_ratio > fwd_ratio,
            "STEP must inflate more than FWD: {step_ratio} vs {fwd_ratio}"
        );
    }

    #[test]
    fn dual_gpu_on_one_aic_hurts_fwd_bwd() {
        // Fig. 7b: with 2 GPUs the contended AIC slows FWD/BWD markedly.
        // The effect is largest where parameter streaming dominates compute
        // (small per-GPU batch), so probe B=1.
        let base_topo = config_a();
        let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
        let w = Workload::new(2, 1, 4096);
        let base = run(&base_topo, mistral_nemo_12b(), w, Policy::DramOnly);
        let naive = run(&cxl_topo, mistral_nemo_12b(), w, Policy::NaiveInterleave);
        let fwd_ratio = naive.fwd_s / base.fwd_s;
        assert!(fwd_ratio > 1.1, "dual-GPU FWD inflation {fwd_ratio}");
        // at B=16 compute hides the transfers — the slowdown concentrates
        // in STEP instead (cf. Fig. 9 where large-batch cells degrade less)
        let w16 = Workload::new(2, 16, 4096);
        let base16 = run(&base_topo, mistral_nemo_12b(), w16, Policy::DramOnly);
        let naive16 = run(&cxl_topo, mistral_nemo_12b(), w16, Policy::NaiveInterleave);
        let fwd16 = naive16.fwd_s / base16.fwd_s;
        assert!(fwd16 < fwd_ratio, "large batch should hide transfers better");
    }

    #[test]
    fn dual_aic_striping_recovers_to_baseline() {
        // Fig. 10: CXL-aware + striping on two AICs ≈ 99–101 % of baseline.
        let base_topo = config_b();
        let cxl_topo = with_dram_capacity(config_b(), 128 * GIB);
        let w = Workload::new(2, 16, 4096);
        let base = run(&base_topo, mistral_nemo_12b(), w, Policy::DramOnly);
        let ours = run(
            &cxl_topo,
            mistral_nemo_12b(),
            w,
            Policy::CxlAware { striping: true },
        );
        let rel = base.iter_s / ours.iter_s;
        assert!(rel > 0.97, "striped dual-AIC relative throughput {rel}");
    }

    #[test]
    fn policy_ordering_is_stable_across_contexts() {
        // baseline ≥ ours ≥ naive for every (C, B) cell we try.
        let base_topo = config_a();
        let cxl_topo = with_dram_capacity(config_a(), 128 * GIB);
        for (c, b) in [(4096, 8), (8192, 4), (16384, 2)] {
            let w = Workload::new(1, b, c);
            let base = run(&base_topo, qwen25_7b(), w, Policy::DramOnly);
            let ours = run(
                &cxl_topo,
                qwen25_7b(),
                w,
                Policy::CxlAware { striping: false },
            );
            let naive = run(&cxl_topo, qwen25_7b(), w, Policy::NaiveInterleave);
            assert!(
                base.iter_s <= ours.iter_s * 1.001 && ours.iter_s <= naive.iter_s * 1.001,
                "ordering broken at C={c} B={b}: base {:.3} ours {:.3} naive {:.3}",
                base.iter_s,
                ours.iter_s,
                naive.iter_s
            );
        }
    }

    #[test]
    fn throughput_scales_with_batch_then_saturates() {
        // Fig. 3 shape: tokens/s grows with batch and flattens.
        let topo = config_a();
        let mut last_tp = 0.0f64;
        let mut gains = Vec::new();
        for b in [1, 2, 4, 8, 16] {
            let br = run(
                &topo,
                mistral_nemo_12b(),
                Workload::new(2, b, 4096),
                Policy::DramOnly,
            );
            let tp = br.tokens_per_sec();
            gains.push(tp / last_tp.max(1e-12));
            last_tp = tp;
        }
        assert!(gains[1] > 1.2, "batch 2 should speed up: {gains:?}");
        let last_gain = gains.last().unwrap();
        assert!(*last_gain < gains[1], "gains should diminish: {gains:?}");
    }

    #[test]
    fn tiny_model_on_dev_topology_runs_fast() {
        let topo = dev_tiny();
        let b = run(
            &topo,
            tiny_2m(),
            Workload::new(2, 4, 512),
            Policy::CxlAware { striping: true },
        );
        assert!(b.iter_s > 0.0 && b.iter_s < 10.0);
    }

    #[test]
    fn deterministic() {
        let topo = with_dram_capacity(config_a(), 128 * GIB);
        let w = Workload::new(2, 8, 4096);
        let a = run(&topo, qwen25_7b(), w, Policy::NaiveInterleave);
        let b = run(&topo, qwen25_7b(), w, Policy::NaiveInterleave);
        assert_eq!(a.iter_s.to_bits(), b.iter_s.to_bits());
    }
}
