//! The CPU-offloading coordinator: the paper's Figure-1 workflow, and —
//! since the schedule-graph IR landed — any fine-tuning scenario
//! expressible as a task DAG.
//!
//! * [`plan`] — Table-I region allocation under a placement policy,
//! * [`evalcache`] — the incremental sweep engine's shared memo layers
//!   (probe / plan / schedule / exec) and per-worker DES arenas,
//! * [`schedule`] — the schedule-graph IR: typed ops + dependency edges,
//! * [`schedules`] — named scenario builders (`zero-offload`,
//!   `grad-accum`, `lora`, `no-act-offload`) and their registry,
//! * [`executor`] — the generic DAG executor over the fabric simulator,
//! * [`iteration`] — the FROZEN pre-IR engine, kept as a differential
//!   parity oracle (`rust/tests/schedule_parity.rs`),
//! * [`metrics`] — legacy phase breakdowns and generalized phase reports,
//! * [`sweep`] — (C, B) grid sweeps over engine × schedule matrices
//!   producing the Fig. 9/10 matrices and the ablation grids.

pub mod evalcache;
pub mod executor;
pub mod iteration;
pub mod metrics;
pub mod plan;
pub mod schedule;
pub mod schedules;
pub mod sweep;

pub use evalcache::{CacheStats, EvalCtx};
pub use executor::{execute, execute_reusing, Execution, RegionTraffic};
pub use iteration::{legacy_simulate_iteration, legacy_simulate_iteration_traced};
pub use metrics::{PhaseBreakdown, PhaseReport, PhaseSpan};
pub use plan::{MemoryPlan, PlanError, PlanReservation, RunConfig, RunProfiles};
pub use schedule::{FlopsTerm, Op, OpId, OpNode, RegionTouch, Schedule};
pub use schedules::{ScheduleBuilder, ScheduleRef};
pub use sweep::{
    sweep_grid, sweep_grid_matrix, sweep_grid_matrix_nocache, sweep_grid_matrix_with_ctx,
    sweep_grid_with_threads, GridPoint, SweepResult,
};

use crate::sim::trace::TraceRecorder;
use crate::topology::SystemTopology;

/// Simulate one iteration of `cfg.schedule`, returning the generalized
/// per-phase report plus the full execution trace.
pub fn simulate_iteration_report(
    topo: &SystemTopology,
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
) -> (PhaseReport, TraceRecorder) {
    assert!(
        cfg.workload.n_gpus <= topo.gpus.len(),
        "workload wants {} GPUs, topology has {}",
        cfg.workload.n_gpus,
        topo.gpus.len()
    );
    let sched = cfg.schedule.build(topo, cfg, plan);
    let ex = executor::execute(topo, &sched);
    (ex.report, ex.trace)
}

/// Simulate one iteration; returns the legacy-style phase breakdown
/// (boundary-based FWD/BWD/STEP view of [`simulate_iteration_report`]).
pub fn simulate_iteration(
    topo: &SystemTopology,
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
) -> PhaseBreakdown {
    simulate_iteration_traced(topo, cfg, plan).0
}

/// Simulate one iteration, additionally recording a full execution trace
/// (exportable as Chrome trace JSON via `TraceRecorder::to_chrome_trace`).
pub fn simulate_iteration_traced(
    topo: &SystemTopology,
    cfg: &RunConfig,
    plan: &MemoryPlan<'_>,
) -> (PhaseBreakdown, TraceRecorder) {
    let (report, trace) = simulate_iteration_report(topo, cfg, plan);
    (report.to_breakdown(), trace)
}
