//! The generic schedule executor: walks any [`Schedule`] DAG over the
//! fabric simulator and reports per-phase timing plus a full trace.
//!
//! This one loop subsumes everything the legacy per-GPU state machine
//! (`offload::iteration`, kept as a frozen differential oracle) did by
//! hand: stripe-completion tracking, event-tag packing, readiness
//! bookkeeping, phase accounting and trace recording.
//!
//! Dispatch rule (the determinism contract, DESIGN.md §9): a node is
//! *runnable* once all its `deps` completed; whenever several nodes become
//! runnable from one completion event they are issued in ascending node
//! index order. Event tags are node indices, so no bit-packing scheme can
//! overflow. Barriers complete the instant they become runnable (no fabric
//! event) and may cascade further nodes within the same dispatch round.
//!
//! Pricing: `Compute` nodes are charged against **their own GPU's**
//! effective FLOP rating — a slow card lengthens its own lane, not the
//! whole fleet (the legacy engine priced every GPU at `gpus[0]`, which the
//! heterogeneous-fleet regression tests in `rust/tests/schedule_parity.rs`
//! now pin down).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use super::metrics::{PhaseReport, PhaseSpan};
use super::schedule::{Op, OpId, RegionTouch, Schedule};
use crate::mem::RegionId;
use crate::sim::fabric::Fabric;
use crate::sim::flow::{Event, FlowSim};
use crate::sim::memmodel::OptimizerMemModel;
use crate::sim::trace::TraceRecorder;
use crate::topology::SystemTopology;

/// DMA traffic the executor actually moved for one plan region, summed
/// over the run's completed `Op::Transfer` nodes (via their
/// [`RegionTouch::Dma`] annotations). This is the simulated-side ledger
/// that validates [`crate::mem::AccessProfile`]s: every node runs exactly
/// once (pinned by the executor contract proptests), so for an annotated
/// schedule these totals must equal the profile pass's predictions.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RegionTraffic {
    /// Bytes moved host→GPU for the region.
    pub h2d_bytes: f64,
    /// Bytes moved GPU→host for the region.
    pub d2h_bytes: f64,
    /// Completed transfer nodes attributed to the region.
    pub touches: u32,
}

/// Everything one executor run produces.
pub struct Execution {
    pub report: PhaseReport,
    pub trace: TraceRecorder,
    /// Node completion order (the contract tests assert it respects edges).
    pub completion_order: Vec<OpId>,
    /// Completion timestamp per node, indexed by `OpId.0`.
    pub completion_s: Vec<f64>,
    /// Per-region DMA ledger, accumulated as transfer nodes complete
    /// (empty for schedules without touch annotations).
    pub region_traffic: BTreeMap<RegionId, RegionTraffic>,
}

/// Per-phase accumulators while the run is in flight.
struct PhaseAcc {
    span_start: f64,
    span_end: f64,
    busy: f64,
    boundary: f64,
    has_boundary_mark: bool,
    has_span: bool,
}

impl PhaseAcc {
    fn new() -> Self {
        Self {
            span_start: f64::INFINITY,
            span_end: 0.0,
            busy: 0.0,
            boundary: 0.0,
            has_boundary_mark: false,
            has_span: false,
        }
    }
}

/// Execute `sched` on `topo`. Panics on an invalid schedule (use
/// [`Schedule::validate`] first for a `Result`).
pub fn execute(topo: &SystemTopology, sched: &Schedule) -> Execution {
    execute_reusing(topo, sched, FlowSim::new(), true).0
}

/// [`execute`] inside a reused DES arena, with optional trace recording.
///
/// `sim` is reset and rebuilt for `topo` (see `Fabric::new_in`), so
/// passing a dirty engine from a previous run is byte-identical to a
/// fresh one — the arena is handed back as the second return value for
/// the caller's next run. With `record_trace = false` the per-span trace
/// strings are never allocated; everything else, including the phase
/// accumulators and the region ledger, is computed identically (the
/// returned `Execution::trace` is simply empty). The sweep's hot path
/// runs this with a per-worker arena and tracing off.
pub fn execute_reusing(
    topo: &SystemTopology,
    sched: &Schedule,
    sim: FlowSim,
    record_trace: bool,
) -> (Execution, FlowSim) {
    // Validation hands back the dependency bookkeeping it had to build
    // anyway (indegrees + dependents), so the adjacency is walked once.
    let (mut remaining_deps, dependents) = sched
        .validated_adjacency(topo)
        .unwrap_or_else(|e| panic!("invalid schedule: {e}"));

    let n = sched.nodes.len();
    let mut fab = Fabric::new_in(topo, sim);
    let mm = OptimizerMemModel::new(topo);
    let mut trace = TraceRecorder::new();

    // Per-node runtime state.
    let mut remaining_stripes: Vec<u32> = vec![0; n];
    let mut started_at: Vec<f64> = vec![0.0; n];
    let mut done: Vec<bool> = vec![false; n];
    let mut completion_s: Vec<f64> = vec![0.0; n];
    let mut completion_order: Vec<OpId> = Vec::with_capacity(n);

    let mut phase_acc: Vec<PhaseAcc> = sched.phases.iter().map(|_| PhaseAcc::new()).collect();

    // Min-heap of runnable node indices: ascending-index dispatch.
    let mut ready: BinaryHeap<Reverse<u32>> = (0..n as u32)
        .filter(|&i| remaining_deps[i as usize] == 0)
        .map(Reverse)
        .collect();

    let mut completed = 0usize;
    let mut region_traffic: BTreeMap<RegionId, RegionTraffic> = BTreeMap::new();

    // Split borrows so the closures below don't fight: completion updates
    // are a small fn over the bookkeeping vectors.
    #[allow(clippy::too_many_arguments)]
    fn complete_node(
        i: usize,
        now: f64,
        sched: &Schedule,
        remaining_deps: &mut [u32],
        dependents: &[Vec<u32>],
        done: &mut [bool],
        completion_s: &mut [f64],
        completion_order: &mut Vec<OpId>,
        phase_acc: &mut [PhaseAcc],
        ready: &mut BinaryHeap<Reverse<u32>>,
        completed: &mut usize,
        region_traffic: &mut BTreeMap<RegionId, RegionTraffic>,
    ) {
        debug_assert!(!done[i], "node {i} completed twice");
        done[i] = true;
        completion_s[i] = now;
        completion_order.push(OpId(i as u32));
        *completed += 1;
        let node = &sched.nodes[i];
        if let Op::Transfer { dir, bytes, .. } = &node.op {
            for t in &node.touches {
                if let RegionTouch::Dma(region) = t {
                    let ledger = region_traffic.entry(*region).or_default();
                    match dir {
                        crate::sim::fabric::Dir::HostToGpu => ledger.h2d_bytes += bytes,
                        crate::sim::fabric::Dir::GpuToHost => ledger.d2h_bytes += bytes,
                    }
                    ledger.touches += 1;
                }
            }
        }
        if node.ends_phase {
            let acc = &mut phase_acc[node.phase];
            acc.boundary = acc.boundary.max(now);
            acc.has_boundary_mark = true;
        }
        for &j in &dependents[i] {
            let r = &mut remaining_deps[j as usize];
            debug_assert!(*r > 0);
            *r -= 1;
            if *r == 0 {
                ready.push(Reverse(j));
            }
        }
    }

    macro_rules! complete {
        ($i:expr, $now:expr) => {
            complete_node(
                $i,
                $now,
                sched,
                &mut remaining_deps,
                &dependents,
                &mut done,
                &mut completion_s,
                &mut completion_order,
                &mut phase_acc,
                &mut ready,
                &mut completed,
                &mut region_traffic,
            )
        };
    }

    macro_rules! record_span {
        ($i:expr, $start:expr, $end:expr) => {{
            let node = &sched.nodes[$i];
            // Tracing is the only skippable effect: the phase accumulators
            // below always run, so timing output is trace-independent.
            if record_trace {
                trace.record(node.name.as_str(), node.lane.as_str(), $start, $end);
            }
            let acc = &mut phase_acc[node.phase];
            acc.span_start = acc.span_start.min($start);
            acc.span_end = acc.span_end.max($end);
            acc.busy += $end - $start;
            acc.has_span = true;
        }};
    }

    // Issue every runnable node in ascending index order; barriers resolve
    // inline and may push more work onto the heap mid-round.
    macro_rules! dispatch {
        () => {
            while let Some(Reverse(idx)) = ready.pop() {
                let i = idx as usize;
                let node = &sched.nodes[i];
                match &node.op {
                    Op::Transfer {
                        gpu,
                        stripes,
                        dir,
                        bytes,
                    } => {
                        let flows = fab.transfer_striped(*gpu, stripes, *dir, *bytes, i as u64);
                        remaining_stripes[i] = flows.len() as u32;
                    }
                    Op::Compute { gpu, work } => {
                        let eff = topo.gpus[gpu.0].effective_flops();
                        let mut secs = 0.0;
                        for t in work {
                            secs += (t.flops / eff) * t.scale;
                        }
                        started_at[i] = fab.now();
                        fab.compute(secs, i as u64);
                    }
                    Op::CpuStep {
                        adam_elements,
                        adam_layout,
                        streams,
                    } => {
                        let mut stream_s = 0.0;
                        for (bytes, layout) in streams {
                            stream_s += mm.stream_time(*bytes, layout);
                        }
                        let secs = mm.step_time(*adam_elements, adam_layout) + stream_s;
                        started_at[i] = fab.now();
                        fab.compute(secs, i as u64);
                    }
                    Op::Barrier => {
                        complete!(i, fab.now());
                    }
                }
            }
        };
    }

    dispatch!();

    while completed < n {
        let Some(ev) = fab.next_event() else {
            panic!(
                "schedule wedged: {completed}/{n} ops completed but the fabric \
                 has no pending events"
            );
        };
        let now = fab.now();
        match ev {
            Event::FlowDone { id, tag } => {
                let i = tag as usize;
                // Record each stripe's span as it lands, consuming its
                // stats so the finished map stays empty over long runs.
                let st = fab.take_stats(id).expect("completed flow has stats");
                record_span!(i, st.issued, st.finished);
                debug_assert!(remaining_stripes[i] > 0, "unexpected stripe for node {i}");
                remaining_stripes[i] -= 1;
                if remaining_stripes[i] == 0 {
                    complete!(i, now);
                }
            }
            Event::TimerFired { tag, .. } => {
                let i = tag as usize;
                record_span!(i, started_at[i], now);
                complete!(i, now);
            }
        }
        dispatch!();
    }

    debug_assert_eq!(
        fab.sim.finished_len(),
        0,
        "every completed flow's stats must have been consumed"
    );

    let iter_s = completion_s.iter().fold(0.0f64, |a, &b| a.max(b));
    let phases = sched
        .phases
        .iter()
        .zip(phase_acc)
        .map(|(name, acc)| {
            let start_s = if acc.has_span { acc.span_start } else { 0.0 };
            let end_s = acc.span_end;
            let boundary_s = if acc.has_boundary_mark {
                acc.boundary
            } else {
                end_s
            };
            PhaseSpan {
                name: name.clone(),
                start_s,
                end_s,
                busy_s: acc.busy,
                boundary_s,
            }
        })
        .collect();

    let exec = Execution {
        report: PhaseReport {
            phases,
            iter_s,
            tokens: sched.tokens,
        },
        trace,
        completion_order,
        completion_s,
        region_traffic,
    };
    (exec, fab.sim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::schedule::{FlopsTerm, OpNode};
    use crate::sim::fabric::Dir;
    use crate::sim::memmodel::OptLayout;
    use crate::topology::presets::dev_tiny;
    use crate::topology::{GpuId, NodeId};
    use crate::util::proptest_lite::{forall, Gen};
    use crate::util::prng::Xoshiro256pp;

    fn node(op: Op, deps: Vec<OpId>, phase: usize) -> OpNode {
        OpNode {
            op,
            deps,
            name: "op".into(),
            lane: "lane".into(),
            phase,
            ends_phase: false,
            touches: vec![],
        }
    }

    fn xfer(gpu: usize, bytes: f64, deps: Vec<OpId>, phase: usize) -> OpNode {
        node(
            Op::Transfer {
                gpu: GpuId(gpu),
                stripes: vec![(NodeId(0), 1.0)],
                dir: Dir::HostToGpu,
                bytes,
            },
            deps,
            phase,
        )
    }

    fn kern(gpu: usize, flops: f64, deps: Vec<OpId>, phase: usize) -> OpNode {
        node(
            Op::Compute {
                gpu: GpuId(gpu),
                work: vec![FlopsTerm::new(flops)],
            },
            deps,
            phase,
        )
    }

    #[test]
    fn chain_completes_in_edge_order() {
        let topo = dev_tiny();
        let mut s = Schedule::new(10);
        let p = s.phase("only");
        let a = s.push(xfer(0, 1e8, vec![], p));
        let b = s.push(kern(0, 1e12, vec![a], p));
        let c = s.push(xfer(0, 1e8, vec![b], p));
        let ex = execute(&topo, &s);
        assert_eq!(ex.completion_order, vec![a, b, c]);
        assert!(ex.completion_s[a.0 as usize] <= ex.completion_s[b.0 as usize]);
        assert!(ex.completion_s[b.0 as usize] <= ex.completion_s[c.0 as usize]);
        assert_eq!(ex.trace.spans().len(), 3);
        assert!(ex.report.iter_s > 0.0);
        assert_eq!(ex.report.tokens, 10);
    }

    #[test]
    fn reused_arena_without_tracing_matches_fresh_execute_bitwise() {
        let topo = dev_tiny();
        let mut s = Schedule::new(10);
        let p = s.phase("only");
        let a = s.push(xfer(0, 1e8, vec![], p));
        let b = s.push(kern(0, 1e12, vec![a], p));
        let c = s.push(kern(1, 3e11, vec![a], p));
        s.push(xfer(1, 2e8, vec![b, c], p));

        let fresh = execute(&topo, &s);
        // Dirty an arena on a different schedule, then reuse it untraced.
        let mut warmup = Schedule::new(0);
        let q = warmup.phase("only");
        warmup.push(xfer(1, 5e8, vec![], q));
        let (_, arena) = execute_reusing(&topo, &warmup, FlowSim::new(), true);
        let (reused, arena) = execute_reusing(&topo, &s, arena, false);

        assert_eq!(reused.report, fresh.report);
        assert_eq!(reused.completion_order, fresh.completion_order);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&reused.completion_s), bits(&fresh.completion_s));
        assert_eq!(reused.region_traffic, fresh.region_traffic);
        // Tracing off means no span strings were recorded …
        assert!(reused.trace.spans().is_empty());
        assert_eq!(fresh.trace.spans().len(), 4);
        // … and the recovered arena is clean enough to go again.
        let (again, _) = execute_reusing(&topo, &s, arena, false);
        assert_eq!(bits(&again.completion_s), bits(&fresh.completion_s));
    }

    #[test]
    fn barrier_cascades_without_fabric_events() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        let p = s.phase("only");
        let a = s.push(xfer(0, 1e8, vec![], p));
        let bar = s.push(node(Op::Barrier, vec![a], p));
        let after = s.push(xfer(1, 1e8, vec![bar], p));
        let ex = execute(&topo, &s);
        assert_eq!(ex.completion_order, vec![a, bar, after]);
        // the barrier completes at the same instant as its dep and emits no span
        assert_eq!(
            ex.completion_s[bar.0 as usize].to_bits(),
            ex.completion_s[a.0 as usize].to_bits()
        );
        assert_eq!(ex.trace.spans().len(), 2);
    }

    #[test]
    fn barrier_only_schedule_runs() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        let p = s.phase("only");
        let a = s.push(node(Op::Barrier, vec![], p));
        s.push(node(Op::Barrier, vec![a], p));
        let ex = execute(&topo, &s);
        assert_eq!(ex.completion_order.len(), 2);
        assert_eq!(ex.report.iter_s, 0.0);
    }

    #[test]
    fn compute_priced_with_own_gpu_rating() {
        // dev_tiny GPUs are identical; slow gpu1 down 2× and check only its
        // kernel stretches.
        let mut topo = dev_tiny();
        topo.gpus[1].mfu /= 2.0;
        let mut s = Schedule::new(0);
        let p = s.phase("only");
        s.push(kern(0, 1e12, vec![], p));
        s.push(kern(1, 1e12, vec![], p));
        let ex = execute(&topo, &s);
        let d0 = ex.completion_s[0];
        let d1 = ex.completion_s[1];
        assert!(
            (d1 / d0 - 2.0).abs() < 1e-9,
            "slow GPU must run its own kernel 2x longer: {d0} vs {d1}"
        );
    }

    #[test]
    fn region_ledger_sums_annotated_transfers() {
        let topo = dev_tiny();
        let r0 = RegionId(0);
        let r1 = RegionId(1);
        let mut s = Schedule::new(0);
        let p = s.phase("only");
        let mut a = xfer(0, 1e8, vec![], p);
        a.touches = vec![RegionTouch::Dma(r0)];
        let a = s.push(a);
        let mut b = xfer(0, 2e8, vec![a], p);
        b.touches = vec![RegionTouch::Dma(r0)];
        let b = s.push(b);
        let mut c = node(
            Op::Transfer {
                gpu: GpuId(1),
                stripes: vec![(NodeId(0), 1.0)],
                dir: Dir::GpuToHost,
                bytes: 5e7,
            },
            vec![b],
            p,
        );
        c.touches = vec![RegionTouch::Dma(r1)];
        s.push(c);
        s.push(kern(0, 1e12, vec![], p)); // unannotated: no ledger entry
        let ex = execute(&topo, &s);
        assert_eq!(ex.region_traffic.len(), 2);
        let t0 = &ex.region_traffic[&r0];
        assert_eq!(t0.h2d_bytes, 3e8);
        assert_eq!(t0.d2h_bytes, 0.0);
        assert_eq!(t0.touches, 2);
        let t1 = &ex.region_traffic[&r1];
        assert_eq!(t1.d2h_bytes, 5e7);
        assert_eq!(t1.touches, 1);
    }

    #[test]
    fn cpu_step_matches_memmodel() {
        let topo = dev_tiny();
        let mm = OptimizerMemModel::new(&topo);
        let elements = 50_000_000u64;
        let layout = OptLayout::dram_only();
        let cast = 1e9f64;
        let expect =
            mm.step_time(elements, &layout) + mm.stream_time(cast, &OptLayout::dram_only());
        let mut s = Schedule::new(0);
        let p = s.phase("step");
        s.push(node(
            Op::CpuStep {
                adam_elements: elements,
                adam_layout: layout,
                streams: vec![(cast, OptLayout::dram_only())],
            },
            vec![],
            p,
        ));
        let ex = execute(&topo, &s);
        assert_eq!(ex.report.iter_s.to_bits(), expect.to_bits());
    }

    #[test]
    #[should_panic(expected = "invalid schedule")]
    fn invalid_schedule_panics() {
        let topo = dev_tiny();
        let mut s = Schedule::new(0);
        s.phase("x");
        s.push(xfer(0, 1e6, vec![OpId(9)], 0));
        execute(&topo, &s);
    }

    // ------------------------------------------------------------------
    // Executor contract property tests (ISSUE 3 satellite): random DAGs
    // are acyclic by construction, validate, run every node exactly once,
    // and complete in an order that respects every edge.
    // ------------------------------------------------------------------

    /// Generates a random schedule seed; the schedule itself is derived
    /// deterministically from it so shrinking stays meaningful.
    struct DagSeed;

    impl Gen for DagSeed {
        type Value = u64;
        fn generate(&self, rng: &mut Xoshiro256pp) -> u64 {
            rng.next_u64()
        }
    }

    fn random_schedule(seed: u64) -> Schedule {
        let mut rng = Xoshiro256pp::seeded(seed);
        let n = rng.range_usize(1, 40);
        let mut s = Schedule::new(rng.range_u64(0, 1 << 20));
        let n_phases = rng.range_usize(1, 3);
        for p in 0..n_phases {
            s.phase(&format!("phase{p}"));
        }
        for i in 0..n {
            // deps point strictly backwards → acyclic by construction
            let mut deps = Vec::new();
            if i > 0 {
                let n_deps = rng.range_usize(0, 3.min(i));
                for _ in 0..n_deps {
                    let d = OpId(rng.range_usize(0, i - 1) as u32);
                    if !deps.contains(&d) {
                        deps.push(d);
                    }
                }
            }
            let phase = rng.range_usize(0, n_phases - 1);
            let gpu = rng.range_usize(0, 1);
            let op = match rng.below(8) {
                0 => Op::Barrier,
                1 => Op::CpuStep {
                    adam_elements: rng.range_u64(1_000, 1_000_000),
                    adam_layout: OptLayout::dram_only(),
                    streams: vec![(rng.range_f64(1e5, 1e8), OptLayout::dram_only())],
                },
                2 | 3 => Op::Compute {
                    gpu: GpuId(gpu),
                    work: vec![FlopsTerm::new(rng.range_f64(1e9, 1e12))],
                },
                _ => Op::Transfer {
                    gpu: GpuId(gpu),
                    stripes: if rng.below(2) == 0 {
                        vec![(NodeId(0), 1.0)]
                    } else {
                        vec![(NodeId(1), 0.5), (NodeId(2), 0.5)]
                    },
                    dir: if rng.below(2) == 0 {
                        Dir::HostToGpu
                    } else {
                        Dir::GpuToHost
                    },
                    bytes: rng.range_f64(1e4, 1e8),
                },
            };
            s.push(OpNode {
                op,
                deps,
                name: format!("op{i}"),
                lane: format!("gpu{gpu}/rand"),
                phase,
                ends_phase: rng.below(5) == 0,
                touches: vec![],
            });
        }
        s
    }

    #[test]
    fn prop_random_dags_validate_and_run_every_node_once() {
        let topo = dev_tiny();
        forall("executor-contract", 0xC0FFEE, 60, &DagSeed, |&seed| {
            let s = random_schedule(seed);
            s.validate(&topo)
                .map_err(|e| format!("seed {seed}: generated DAG invalid: {e}"))?;
            let ex = execute(&topo, &s);
            if ex.completion_order.len() != s.len() {
                return Err(format!(
                    "seed {seed}: {} of {} nodes completed",
                    ex.completion_order.len(),
                    s.len()
                ));
            }
            // exactly once: completion order is a permutation
            let mut seen = vec![false; s.len()];
            for id in &ex.completion_order {
                if seen[id.0 as usize] {
                    return Err(format!("seed {seed}: node {} completed twice", id.0));
                }
                seen[id.0 as usize] = true;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_completion_order_respects_edges() {
        let topo = dev_tiny();
        forall("executor-edge-order", 0xBEEF, 60, &DagSeed, |&seed| {
            let s = random_schedule(seed);
            let ex = execute(&topo, &s);
            let mut pos = vec![0usize; s.len()];
            for (k, id) in ex.completion_order.iter().enumerate() {
                pos[id.0 as usize] = k;
            }
            for (i, node) in s.nodes.iter().enumerate() {
                for d in &node.deps {
                    let (di, dd) = (d.0 as usize, i);
                    if pos[di] >= pos[dd] {
                        return Err(format!(
                            "seed {seed}: node {dd} completed before its dep {di}"
                        ));
                    }
                    if ex.completion_s[di] > ex.completion_s[dd] {
                        return Err(format!(
                            "seed {seed}: dep {di} completed later in time than {dd}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_execution_is_deterministic() {
        let topo = dev_tiny();
        forall("executor-determinism", 0xFEED, 20, &DagSeed, |&seed| {
            let s = random_schedule(seed);
            let a = execute(&topo, &s);
            let b = execute(&topo, &s);
            if a.trace.digest() != b.trace.digest() {
                return Err(format!("seed {seed}: two runs diverged"));
            }
            if a.completion_order != b.completion_order {
                return Err(format!("seed {seed}: completion order diverged"));
            }
            Ok(())
        });
    }
}
