//! Data-parallel helpers over `std::thread::scope` (no `rayon` offline).
//!
//! The optimizer hot path and the bench harness need exactly two shapes of
//! parallelism:
//!   * [`par_chunks_mut`] — split a mutable slice into near-equal chunks and
//!     run a closure per chunk on its own thread (the ZeRO-Offload
//!     OpenMP-parallel-for equivalent),
//!   * [`par_map`] — map a closure over indexed work items with a bounded
//!     worker count and collect results in order.
//!
//! Threads are spawned per call; for the multi-millisecond optimizer
//! chunks this cost (~10 µs/thread) is noise, and it keeps the code free of
//! global state.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: physical parallelism,
/// clamped to something sane.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 128)
}

/// Split `data` into `nthreads` near-equal contiguous chunks and invoke
/// `f(chunk_index, element_offset, chunk)` on each, in parallel.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], nthreads: usize, f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads == 1 {
        f(0, 0, data);
        return;
    }
    let base = n / nthreads;
    let extra = n % nthreads;
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut offset = 0usize;
        for i in 0..nthreads {
            let len = base + usize::from(i < extra);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            let fr = &f;
            let off = offset;
            scope.spawn(move || fr(i, off, chunk));
            offset += len;
        }
    });
}

/// Parallel map over `nitems` indexed work items with at most `nworkers`
/// threads; results are returned in item order. Work stealing is a shared
/// atomic cursor — items should be coarse enough to amortize it.
pub fn par_map<R: Send, F>(nitems: usize, nworkers: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    if nitems == 0 {
        return Vec::new();
    }
    let nworkers = nworkers.max(1).min(nitems);
    if nworkers == 1 {
        return (0..nitems).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..nitems).map(|_| None).collect();
    {
        // Hand each worker disjoint &mut access via raw parts; simpler and
        // still safe is a mutex-free approach with per-item cells:
        let cells: Vec<std::sync::Mutex<&mut Option<R>>> =
            slots.iter_mut().map(std::sync::Mutex::new).collect();
        std::thread::scope(|scope| {
            for _ in 0..nworkers {
                let cursor = &cursor;
                let cells = &cells;
                let f = &f;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= nitems {
                        break;
                    }
                    let r = f(i);
                    **cells[i].lock().unwrap() = Some(r);
                });
            }
        });
    }
    slots.into_iter().map(|s| s.expect("worker filled slot")).collect()
}

/// Parallel fold: run `f(chunk_index, range)` per contiguous index range and
/// combine the per-thread results with `combine`.
pub fn par_ranges<R: Send, F, C>(n: usize, nthreads: usize, f: F, combine: C) -> Option<R>
where
    F: Fn(usize, std::ops::Range<usize>) -> R + Sync,
    C: Fn(R, R) -> R,
{
    if n == 0 {
        return None;
    }
    let nthreads = nthreads.max(1).min(n);
    let base = n / nthreads;
    let extra = n % nthreads;
    let mut results: Vec<R> = Vec::with_capacity(nthreads);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nthreads);
        let mut start = 0usize;
        for i in 0..nthreads {
            let len = base + usize::from(i < extra);
            let range = start..start + len;
            start += len;
            let fr = &f;
            handles.push(scope.spawn(move || fr(i, range)));
        }
        for h in handles {
            results.push(h.join().expect("worker panicked"));
        }
    });
    results.into_iter().reduce(combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 10_007];
        par_chunks_mut(&mut v, 8, |_, _, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_offsets_are_correct() {
        let mut v: Vec<usize> = vec![0; 1000];
        par_chunks_mut(&mut v, 7, |_, offset, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = offset + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn chunks_single_thread_path() {
        let mut v = vec![1u64; 17];
        par_chunks_mut(&mut v, 1, |idx, off, chunk| {
            assert_eq!((idx, off), (0, 0));
            assert_eq!(chunk.len(), 17);
        });
    }

    #[test]
    fn map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn map_utilizes_multiple_threads() {
        // The parallel-sweep contract: par_map genuinely fans work across
        // worker threads. Each closure rendezvouses (yielding, bounded)
        // until a second worker has checked in, so the assertion holds even
        // on throttled single-core CI runners — one worker cannot satisfy
        // the rendezvous by draining the queue alone.
        let arrived = AtomicUsize::new(0);
        let ids = par_map(4, 4, |_| {
            arrived.fetch_add(1, Ordering::SeqCst);
            let t0 = std::time::Instant::now();
            while arrived.load(Ordering::SeqCst) < 2 && t0.elapsed().as_secs() < 5 {
                std::thread::yield_now();
            }
            std::thread::current().id()
        });
        let distinct: std::collections::HashSet<_> = ids.into_iter().collect();
        assert!(
            distinct.len() >= 2,
            "expected ≥2 worker threads, saw {}",
            distinct.len()
        );
    }

    #[test]
    fn map_empty() {
        let out: Vec<u32> = par_map(0, 8, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn ranges_fold_sum() {
        let total = par_ranges(1_000, 6, |_, r| r.sum::<usize>(), |a, b| a + b).unwrap();
        assert_eq!(total, 999 * 1000 / 2);
    }

    #[test]
    fn more_threads_than_items() {
        let mut v = vec![0u8; 3];
        par_chunks_mut(&mut v, 64, |_, _, c| {
            for x in c {
                *x = 7;
            }
        });
        assert_eq!(v, vec![7, 7, 7]);
        let out = par_map(2, 64, |i| i + 1);
        assert_eq!(out, vec![1, 2]);
    }
}
