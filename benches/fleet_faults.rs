//! Fault-recovery bench: the pinned 100-job mixed-context trace on the
//! §V-B-shaped host (config-a, 128 GiB DRAM) hit by the derived pinned
//! fault trace (link degrade + CXL AIC hot-remove + restore inside the
//! busiest AIC window), replayed under every registered recovery policy.
//!
//! Gates (enforced in CI via `--smoke`):
//! * `evacuate` ≥ `checkpoint-restart` ≥ `fail-stop` on completed jobs,
//!   and `evacuate` strictly beats `fail-stop` on both completions and
//!   goodput (useful tokens per second of makespan).
//! * bit-identical result digests across reruns (the determinism
//!   contract extends to faulted runs).
//!
//! Results land in `bench_out/fleet_faults/` and in `BENCH_faults.json`
//! (override: `CXLFINE_BENCH_FAULTS_OUT`), which the CI bench-smoke job
//! uploads on every push so the degradation-recovery trajectory is
//! recorded alongside the fleet-throughput one.

use std::time::Instant;

use cxlfine::fleet::{
    faults, mixed_trace_with_xl, pinned_faults_from_baseline, scheduler, simulate_fleet,
    simulate_fleet_faulted,
};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("fleet_faults");
    let topo = with_dram_capacity(config_a(), 128 * GIB);
    let threads = cxlfine::util::threadpool::default_threads();
    let policy = scheduler::by_name("placement-aware").unwrap();

    let trace = mixed_trace_with_xl(&topo, 1007, 92, 8);
    assert_eq!(
        trace.jobs.len(),
        100,
        "the XL static/lifetime gap cell must exist at 128 GiB DRAM"
    );
    // The fault window derives from the fault-free run: degrade a CXL
    // link a quarter into the busiest AIC-resident span, hot-remove the
    // AIC halfway through, restore it at three quarters.
    let baseline = simulate_fleet(&topo, &trace, &policy, threads);
    let fault_trace = pinned_faults_from_baseline(&topo, &baseline);
    fault_trace.validate(&topo).unwrap();
    println!(
        "pinned fault trace: {} events (digest {:016x}) on {}",
        fault_trace.events.len(),
        fault_trace.digest(),
        topo.name
    );

    let mut t = Table::new(&[
        "recovery",
        "wall",
        "completed",
        "failed",
        "interrupts",
        "migrations",
        "goodput tok/s",
        "lost tok",
        "recovery s",
    ])
    .left(0);
    let mut raws = Vec::new();
    let mut by_name = Vec::new();
    for recovery in faults::registry() {
        let t0 = Instant::now();
        let res = simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, threads);
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        t.row(trow![
            recovery.name(),
            format!("{wall:.2}s"),
            res.completed(),
            res.failed(),
            res.interruptions(),
            res.migrations(),
            format!("{:.0}", res.goodput_tokens_per_sec()),
            res.lost_tokens(),
            format!("{:.0}", res.recovery_s())
        ]);
        let mut cell = JsonObj::new();
        cell.set("recovery", recovery.name());
        cell.set("wall_s", wall);
        cell.set("completed", res.completed());
        cell.set("failed", res.failed());
        cell.set("interruptions", res.interruptions());
        cell.set("migrations", res.migrations());
        cell.set("goodput_tokens_per_sec", res.goodput_tokens_per_sec());
        cell.set("lost_tokens", res.lost_tokens());
        cell.set("recovery_s", res.recovery_s());
        cell.set("digest", format!("{:016x}", res.digest()));
        raws.push(Json::Obj(cell));
        by_name.push((recovery.name().to_string(), res));
    }
    let get = |name: &str| {
        by_name
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| r)
            .expect("registered recovery policy ran")
    };
    let (fs, cr, ev) = (get("fail-stop"), get("checkpoint-restart"), get("evacuate"));
    // The graded recovery ladder, with the strict beat at its ends.
    assert!(
        ev.completed() >= cr.completed() && cr.completed() >= fs.completed(),
        "completions must grade evacuate ≥ checkpoint-restart ≥ fail-stop: {} / {} / {}",
        ev.completed(),
        cr.completed(),
        fs.completed()
    );
    assert!(
        ev.completed() > fs.completed(),
        "evacuate must strictly beat fail-stop on completions: {} vs {}",
        ev.completed(),
        fs.completed()
    );
    assert!(
        ev.goodput_tokens_per_sec() > fs.goodput_tokens_per_sec(),
        "evacuate must strictly beat fail-stop on goodput: {:.1} vs {:.1} tok/s",
        ev.goodput_tokens_per_sec(),
        fs.goodput_tokens_per_sec()
    );
    // Determinism: a single-threaded rerun is bit-identical.
    let recovery = faults::by_name("evacuate").unwrap();
    let rerun = simulate_fleet_faulted(&topo, &trace, &policy, &fault_trace, &recovery, 1);
    assert_eq!(rerun.digest(), ev.digest(), "faulted rerun must be bit-identical");

    report.section("recovery_policies", t, Json::Arr(raws.clone()));

    let mut root = JsonObj::new();
    root.set("bench", "fleet_faults");
    root.set("smoke", smoke);
    root.set("policy", policy.name());
    root.set("trace_digest", format!("{:016x}", trace.digest()));
    root.set("fault_digest", format!("{:016x}", fault_trace.digest()));
    root.set("n_faults", fault_trace.events.len());
    root.set("recoveries", Json::Arr(raws));
    let out =
        std::env::var("CXLFINE_BENCH_FAULTS_OUT").unwrap_or_else(|_| "BENCH_faults.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[fleet_faults] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
