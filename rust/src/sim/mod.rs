//! Discrete-event simulation of the memory system and interconnect.
//!
//! Two complementary models:
//! * [`flow`] + [`fabric`] — flow-level DES with max-min fair bandwidth
//!   sharing for all DMA traffic (GPU loads/offloads, Fig. 6 contention),
//! * [`memmodel`] — calibrated timing of the CPU-side optimizer step as a
//!   function of data placement (Fig. 5 / Fig. 7 STEP).
//!
//! Calibration constants live in `topology::presets`; DESIGN.md §6 lists
//! their sources.

pub mod fabric;
pub mod flow;
pub mod memmodel;
pub mod reference;
pub mod trace;

pub use fabric::{Dir, Fabric, DMA_SETUP_S};
pub use flow::{CapacityModel, Event, FlowId, FlowSim, FlowStats, ResourceId, SimTime, TimerId};
pub use memmodel::{AccessMode, OptLayout, OptimizerMemModel};
