//! Capacity fit: the largest (model, context) cell each placement engine
//! can fit under a constrained DRAM budget, with **static** (whole-run
//! sum) versus **lifetime-aware** (per-phase peak) capacity accounting.
//!
//! This is the memory-side headline of the tensor-lifetime IR: activation
//! checkpoints are dead during the optimizer step and the fp32 working
//! set is dead until it, so the per-phase peak is far below the static
//! sum — timeline accounting admits contexts the static check rejects as
//! OOM (most dramatically for the DRAM-only baseline, where every byte
//! competes for the same node).
//!
//! Results land in `bench_out/capacity_fit/` and in `BENCH_mem.json`
//! (override: `CXLFINE_BENCH_MEM_OUT`), which the CI bench-smoke job
//! uploads on every push (`--smoke` preset) so the capacity trajectory is
//! recorded alongside the DES and schedule ones.

use cxlfine::mem::engine;
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::{mistral_nemo_12b, qwen25_7b};
use cxlfine::model::ModelConfig;
use cxlfine::offload::{MemoryPlan, RunConfig};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::topology::SystemTopology;
use cxlfine::trow;
use cxlfine::util::bench::BenchReport;
use cxlfine::util::json::{Json, JsonObj};
use cxlfine::util::table::Table;
use cxlfine::util::units::{fmt_bytes, GIB};

/// Largest ladder context that fits (0 = not even the smallest rung).
fn largest_fitting_context(
    topo: &SystemTopology,
    model: &ModelConfig,
    batch: usize,
    engine: &cxlfine::mem::EngineRef,
    lifetime_aware: bool,
    ladder: &[usize],
) -> usize {
    let mut best = 0;
    for &c in ladder {
        let cfg = RunConfig::new(model.clone(), Workload::new(1, batch, c), engine.clone());
        let fits = if lifetime_aware {
            MemoryPlan::fits_lifetime_aware(topo, &cfg)
        } else {
            MemoryPlan::fits(topo, &cfg)
        };
        if fits {
            best = c;
        } else {
            // fit is monotone in context (activations only grow)
            break;
        }
    }
    best
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = BenchReport::new("capacity_fit");

    // (model, DRAM budget that makes capacity bind without starving the
    // fp32 working set at batch 8)
    let cells: Vec<(ModelConfig, u64)> = if smoke {
        vec![(qwen25_7b(), 192 * GIB)]
    } else {
        vec![(qwen25_7b(), 192 * GIB), (mistral_nemo_12b(), 320 * GIB)]
    };
    let step = if smoke { 8192 } else { 4096 };
    let ladder: Vec<usize> = (1..=(131072 / step)).map(|i| i * step).collect();
    let batch = 8usize;

    let mut json_cells = Vec::new();
    for (model, dram) in &cells {
        let topo = with_dram_capacity(config_a(), *dram);
        let mut t = Table::new(&[
            "engine",
            "static max ctx",
            "lifetime max ctx",
            "admitted extra",
        ])
        .left(0);
        let mut raws = Vec::new();
        for eng in engine::registry() {
            let stat = largest_fitting_context(&topo, model, batch, &eng, false, &ladder);
            let life = largest_fitting_context(&topo, model, batch, &eng, true, &ladder);
            assert!(
                life >= stat,
                "{}/{}: lifetime accounting must never fit less (static {stat}, lifetime {life})",
                model.name,
                eng.name()
            );
            if eng.name() == "baseline-dram" {
                // Every byte competes for DRAM, so the dead-window overlay
                // must admit strictly longer contexts.
                assert!(
                    life > stat,
                    "{}: baseline-dram must gain context from lifetime accounting \
                     (static {stat}, lifetime {life})",
                    model.name
                );
            }
            let gain = if stat > 0 {
                format!("{:+.0}%", 100.0 * (life as f64 / stat as f64 - 1.0))
            } else if life > 0 {
                "inf".into()
            } else {
                "-".into()
            };
            t.row(trow![eng.name(), stat, life, gain]);
            let mut cell = JsonObj::new();
            cell.set("engine", eng.name());
            cell.set("static_max_context", stat);
            cell.set("lifetime_max_context", life);
            raws.push(Json::Obj(cell));
        }
        println!(
            "{} @ batch {batch}, DRAM {} (ladder step {step}, max {})",
            model.name,
            fmt_bytes(*dram),
            ladder.last().unwrap()
        );
        let series = model.name.replace('.', "_");
        report.section(&series, t, Json::Arr(raws.clone()));
        json_cells.push(Json::Obj({
            let mut js = JsonObj::new();
            js.set("model", model.name.as_str());
            js.set("dram_bytes", *dram);
            js.set("batch", batch);
            js.set("engines", Json::Arr(raws));
            js
        }));
    }

    let mut root = JsonObj::new();
    root.set("bench", "capacity_fit");
    root.set("smoke", smoke);
    root.set("ladder_step", step);
    root.set("cells", Json::Arr(json_cells));
    let out = std::env::var("CXLFINE_BENCH_MEM_OUT").unwrap_or_else(|_| "BENCH_mem.json".into());
    let payload = Json::Obj(root).to_string_pretty();
    match std::fs::write(&out, &payload) {
        Ok(()) => println!("\n[capacity_fit] wrote {out}"),
        Err(e) => eprintln!("warn: could not write {out}: {e}"),
    }
    report.finish();
}
