"""L1 fused linear-cross-entropy kernel vs the materialized oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_ce, ref

jax.config.update("jax_platform_name", "cpu")


def rand_case(seed, tokens, hidden, vocab):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (tokens, hidden), jnp.float32)
    emb = jax.random.normal(k2, (vocab, hidden), jnp.float32) * 0.05
    labels = jax.random.randint(k3, (tokens,), 0, vocab)
    return x, emb, labels


@settings(max_examples=25, deadline=None)
@given(
    tokens=st.sampled_from([8, 32, 96, 128]),
    hidden=st.sampled_from([16, 32, 64]),
    vocab=st.sampled_from([64, 256, 1000, 2048]),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_matches_reference(tokens, hidden, vocab, seed):
    x, emb, labels = rand_case(seed, tokens, hidden, vocab)
    got = fused_ce.fused_linear_cross_entropy(x, emb, labels)
    want = ref.linear_cross_entropy(x, emb, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    block_rows=st.sampled_from([8, 16, 64, 128]),
    block_vocab=st.sampled_from([32, 128, 512]),
)
def test_block_size_invariance(block_rows, block_vocab):
    x, emb, labels = rand_case(3, 64, 32, 512)
    lse, ll = fused_ce.fused_ce_stats(x, emb, labels, block_rows, block_vocab)
    lse_ref, ll_ref = ref.lse_and_label_logit(x, emb, labels)
    np.testing.assert_allclose(lse, lse_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ll, ll_ref, rtol=1e-5, atol=1e-5)


def test_gradients_match_reference():
    x, emb, labels = rand_case(9, 48, 24, 300)
    gx, gemb = jax.grad(fused_ce.fused_linear_cross_entropy, argnums=(0, 1))(
        x, emb, labels
    )
    rx, remb = jax.grad(
        lambda x, emb: ref.linear_cross_entropy(x, emb, labels), argnums=(0, 1)
    )(x, emb)
    np.testing.assert_allclose(gx, rx, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gemb, remb, rtol=1e-5, atol=1e-5)


def test_uniform_logits_give_log_vocab():
    vocab = 512
    x = jnp.zeros((16, 32), jnp.float32)
    emb = jnp.ones((vocab, 32), jnp.float32)
    labels = jnp.zeros((16,), jnp.int32)
    loss = fused_ce.fused_linear_cross_entropy(x, emb, labels)
    np.testing.assert_allclose(loss, np.log(vocab), rtol=1e-5)


def test_perfect_prediction_loss_near_zero():
    # one-hot-ish embeddings with a huge margin on the label row
    vocab, hidden = 64, 64
    emb = jnp.eye(vocab, hidden) * 50.0
    labels = jnp.arange(16, dtype=jnp.int32)
    x = jnp.eye(16, hidden)  # row t points at label t
    loss = fused_ce.fused_linear_cross_entropy(x, emb, labels)
    assert float(loss) < 1e-3


def test_label_logit_extraction_extremes():
    # labels at the first and last vocab tile boundaries
    x, emb, _ = rand_case(17, 32, 16, 1024)
    labels = jnp.array([0, 1023] * 16, jnp.int32)
    _, ll = fused_ce.fused_ce_stats(x, emb, labels)
    _, ll_ref = ref.lse_and_label_logit(x, emb, labels)
    np.testing.assert_allclose(ll, ll_ref, rtol=1e-5, atol=1e-5)


def test_peak_memory_is_sub_naive():
    # §8 structural target: fused peak ≪ tokens·vocab
    tokens, hidden, vocab = 32768, 4096, 152064
    fused = fused_ce.peak_live_floats(tokens, hidden, vocab)
    naive = tokens * vocab
    assert fused < naive / 100
