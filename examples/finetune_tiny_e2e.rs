//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Loads the AOT-compiled JAX/Pallas artifacts (L2+L1) through the PJRT
//! runtime, fine-tunes the tiny causal-LM on a synthetic corpus for a few
//! hundred steps with the Figure-1 offload workflow (streamed blocks, host
//! checkpoint arena, Rust CPU Adam = L3), logs the loss curve, and — to tie
//! the functional and timing planes together — plans the same run's memory
//! placement on the Config-A topology and reports what the placement
//! policies would do to it at 7B/12B scale.
//!
//! ```bash
//! make artifacts && cargo run --release --example finetune_tiny_e2e
//! ```
//!
//! The resulting loss curve is recorded in EXPERIMENTS.md §End-to-end.

use cxlfine::mem::Policy;
use cxlfine::model::footprint::Workload;
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::optim::AdamHp;
use cxlfine::runtime::Runtime;
use cxlfine::topology::presets::dev_tiny;
use cxlfine::train::{batch_shape, Trainer, TrainerCfg};
use cxlfine::util::units::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("CXLFINE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::var("CXLFINE_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // ---- L2/L1: load the AOT artifacts --------------------------------
    let rt = Runtime::load(&artifacts)?;
    let m = rt.manifest();
    let (b, c) = batch_shape(&rt)?;
    println!(
        "loaded {} artifact entries on {} — model: {} layers, H={}, V={}, {:.2}M params",
        m.entries.len(),
        rt.platform(),
        m.meta_usize("layers")?,
        m.meta_usize("hidden")?,
        m.meta_usize("vocab")?,
        m.meta_usize("n_params")? as f64 / 1e6
    );

    // ---- L3: the functional fine-tuning loop ---------------------------
    let cfg = TrainerCfg {
        batch: b,
        context: c,
        steps,
        hp: AdamHp {
            lr: 3e-3,
            ..Default::default()
        },
        log_every: 20,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&rt, cfg)?;
    let t0 = std::time::Instant::now();
    let logs = trainer.train()?;
    let wall = t0.elapsed().as_secs_f64();

    let first = logs[0].loss;
    let last5: f64 = logs[logs.len().saturating_sub(5)..]
        .iter()
        .map(|l| l.loss)
        .sum::<f64>()
        / 5.0;
    let tokens = (steps * b * c) as f64;
    println!("\n=== end-to-end result ===");
    println!("steps: {steps}   tokens: {tokens:.0}   wall: {wall:.1}s   {:.0} tok/s", tokens / wall);
    println!("loss: {first:.4} → {last5:.4} (mean of last 5)");
    println!(
        "checkpoint arena per step: {} (the 'offloaded activations' of Fig. 1)",
        fmt_bytes(logs[0].checkpoint_bytes)
    );

    // persist the loss curve for EXPERIMENTS.md
    std::fs::create_dir_all("bench_out").ok();
    let mut csv = String::from("step,loss,wall_s,checkpoint_bytes\n");
    for l in &logs {
        csv.push_str(&format!(
            "{},{:.6},{:.4},{}\n",
            l.step, l.loss, l.wall_s, l.checkpoint_bytes
        ));
    }
    std::fs::write("bench_out/e2e_loss_curve.csv", &csv)?;
    println!("wrote bench_out/e2e_loss_curve.csv");

    // ---- timing plane: the same workflow, placed on real hardware ------
    println!("\n=== the same workflow on the dev topology (timing plane) ===");
    let topo = dev_tiny();
    let model = cxlfine::model::presets::tiny_2m();
    let w = Workload::new(2, b, c);
    for policy in [
        Policy::DramOnly,
        Policy::NaiveInterleave,
        Policy::CxlAware { striping: true },
    ] {
        let cfg = RunConfig::new(model.clone(), w, policy);
        let plan = MemoryPlan::build(&topo, &cfg)?;
        let bd = simulate_iteration(&topo, &cfg, &plan);
        println!(
            "  {:<22} {:.1} ms/iter ({:.0} tok/s simulated)",
            policy.name(),
            bd.iter_s * 1e3,
            bd.tokens_per_sec()
        );
    }

    if last5 >= first * 0.7 {
        anyhow::bail!("loss did not improve enough: {first:.3} → {last5:.3}");
    }
    println!("\nOK: all three layers compose; learning verified.");
    Ok(())
}
