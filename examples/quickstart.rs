//! Quickstart: plan memory for a fine-tuning run, simulate one iteration
//! under the three placement policies, and print the paper's comparison.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cxlfine::mem::Policy;
use cxlfine::model::footprint::{Footprint, Workload};
use cxlfine::model::presets::qwen25_7b;
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::topology::presets::{config_a, with_dram_capacity};
use cxlfine::util::units::{fmt_bytes, fmt_secs, GIB};

fn main() -> anyhow::Result<()> {
    // The paper's single-AIC platform (Table II, Config A)...
    let baseline_host = config_a();
    // ...but CXL-policy runs only get 128 GiB of local DRAM (§V-B).
    let cxl_host = with_dram_capacity(config_a(), 128 * GIB);

    let model = qwen25_7b();
    let workload = Workload::new(1, 8, 4096); // 1 GPU, batch 8, 4K context

    // Table I: where does the memory go?
    let fp = Footprint::compute(&model, &workload);
    println!(
        "{} ({}) with {} GPU(s), B={}, C={}:",
        model.name,
        model.params_label(),
        workload.n_gpus,
        workload.batch,
        workload.context
    );
    println!("  fp32 P+G+O (latency-critical): {}", fmt_bytes(fp.latency_critical()));
    println!("  bf16 P+G+A (GPU-transfer):     {}", fmt_bytes(fp.gpu_transfer()));
    println!("  total system memory:           {}\n", fmt_bytes(fp.total()));

    // Simulate one iteration under each policy.
    let mut baseline_tps = 0.0;
    for policy in [
        Policy::DramOnly,
        Policy::NaiveInterleave,
        Policy::CxlAware { striping: false },
    ] {
        let host = if policy == Policy::DramOnly {
            &baseline_host
        } else {
            &cxl_host
        };
        let cfg = RunConfig::new(model.clone(), workload, policy);
        let plan = MemoryPlan::build(host, &cfg)?;
        let b = simulate_iteration(host, &cfg, &plan);
        if policy == Policy::DramOnly {
            baseline_tps = b.tokens_per_sec();
        }
        println!(
            "{:<22} iter {:>10}  (FWD {} | BWD {} | STEP {})  {:.0} tok/s = {:>5.1}% of baseline",
            policy.name(),
            fmt_secs(b.iter_s),
            fmt_secs(b.fwd_s),
            fmt_secs(b.bwd_s),
            fmt_secs(b.step_s),
            b.tokens_per_sec(),
            100.0 * b.tokens_per_sec() / baseline_tps
        );
    }
    println!("\n→ naive CXL loses throughput in STEP; CXL-aware allocation recovers it (Fig. 9a).");
    Ok(())
}
