//! The shared event-key encoding (DESIGN.md §14).
//!
//! Both discrete-event layers key their queues with the same triple
//! `time_bits · kind · seq`:
//!
//! * `time_bits` — the IEEE-754 bit pattern of the (finite, non-negative)
//!   event time. For non-negative finite doubles the bit pattern is
//!   order-isomorphic to the value, so a plain `u64` compare *is* the
//!   time compare — no `OrdTime` wrapper, no NaN branches on the hot
//!   path. `-0.0` is folded to `+0.0` at construction so the two zero
//!   encodings can never reorder.
//! * `kind` — the per-layer event-class rank broken at equal times. The
//!   fleet pins completions(0) < faults(1) < arrivals(2) < requeues(3);
//!   FlowSim uses activation(0) vs timer(1) across its two queues.
//! * `seq` — a monotonically issued sequence number making every key
//!   unique and the total order exhaustive (equal-key order would be
//!   backend-defined, so the layers never issue duplicate keys).
//!
//! The derived lexicographic `Ord` over the struct fields is exactly the
//! dispatch order the simulators promise in their determinism contracts.

/// A totally ordered event key: `(time_bits, kind, seq)` lexicographic.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
pub struct EventKey {
    time_bits: u64,
    kind: u8,
    seq: u64,
}

impl EventKey {
    /// Build a key at time `t`. Panics on NaN, infinite, or negative
    /// times — those are logic errors in the caller, and silently
    /// accepting them would corrupt the bit-pattern order.
    #[inline]
    pub fn new(t: f64, kind: u8, seq: u64) -> Self {
        let t = t + 0.0; // fold -0.0 → +0.0 so to_bits is order-isomorphic
        assert!(
            t.is_finite() && t >= 0.0,
            "event time must be finite and non-negative, got {t}"
        );
        EventKey {
            time_bits: t.to_bits(),
            kind,
            seq,
        }
    }

    /// The event time as a double.
    #[inline]
    pub fn time(&self) -> f64 {
        f64::from_bits(self.time_bits)
    }

    /// The raw bit pattern (what cohort equality is defined over).
    #[inline]
    pub fn time_bits(&self) -> u64 {
        self.time_bits
    }

    /// The event-class rank.
    #[inline]
    pub fn kind(&self) -> u8 {
        self.kind
    }

    /// The uniquifying sequence number.
    #[inline]
    pub fn seq(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_is_time_then_kind_then_seq() {
        let a = EventKey::new(1.0, 3, 9);
        let b = EventKey::new(2.0, 0, 0);
        assert!(a < b, "earlier time wins regardless of kind/seq");
        let c = EventKey::new(1.0, 0, 9);
        let d = EventKey::new(1.0, 1, 0);
        assert!(c < d, "at equal times the kind rank breaks the tie");
        let e = EventKey::new(1.0, 1, 1);
        assert!(d < e, "at equal (time, kind) the sequence number decides");
    }

    #[test]
    fn time_bits_compare_like_times_for_nonnegative_finites() {
        let ts = [0.0, 1e-300, 1e-9, 0.5, 1.0, 1.0 + f64::EPSILON, 1e300];
        for w in ts.windows(2) {
            let a = EventKey::new(w[0], 0, 0);
            let b = EventKey::new(w[1], 0, 0);
            assert!(a < b && a.time_bits() < b.time_bits());
        }
    }

    #[test]
    fn negative_zero_is_folded_to_positive_zero() {
        let a = EventKey::new(-0.0, 0, 0);
        let b = EventKey::new(0.0, 0, 0);
        assert_eq!(a, b);
        assert_eq!(a.time_bits(), 0.0_f64.to_bits());
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn nan_time_is_rejected() {
        EventKey::new(f64::NAN, 0, 0);
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn negative_time_is_rejected() {
        EventKey::new(-1.0, 0, 0);
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn infinite_time_is_rejected() {
        EventKey::new(f64::INFINITY, 0, 0);
    }

    #[test]
    fn accessors_round_trip() {
        let k = EventKey::new(3.5, 2, 77);
        assert_eq!(k.time(), 3.5);
        assert_eq!(k.time_bits(), 3.5_f64.to_bits());
        assert_eq!(k.kind(), 2);
        assert_eq!(k.seq(), 77);
    }
}
