//! Gradient accumulation: K micro-batches stream through the Fig. 1
//! forward+backward pipeline before a single CPU optimizer step — the
//! schedule the legacy state machine could not express (its phase flags
//! were single-shot per GPU).
//!
//! Why it matters for the paper's question: accumulation multiplies the
//! *transfer* phases (params re-stream and checkpoints round-trip every
//! micro-batch) while the latency-critical STEP runs once, so the
//! CXL-vs-DRAM placement trade-off tilts toward bulk-bandwidth — the
//! opposite corner from `lora`. `benches/schedule_ablation.rs` quantifies
//! both against `zero-offload`.

use super::super::plan::{MemoryPlan, RunConfig};
use super::super::schedule::Schedule;
use super::zero_offload::{build_fig1_passes, full_model_cpu_step, Fig1Shape};
use super::ScheduleBuilder;
use crate::topology::SystemTopology;

/// Default K when the registry name carries no `:K` parameter.
pub const DEFAULT_MICRO_BATCHES: usize = 4;

pub struct GradAccum {
    micro_batches: usize,
    name: String,
}

impl GradAccum {
    pub fn new(micro_batches: usize) -> Self {
        assert!(micro_batches >= 1);
        Self {
            micro_batches,
            name: format!("grad-accum:{micro_batches}"),
        }
    }
}

impl ScheduleBuilder for GradAccum {
    fn name(&self) -> &str {
        &self.name
    }

    fn build(&self, _topo: &SystemTopology, cfg: &RunConfig, plan: &MemoryPlan<'_>) -> Schedule {
        // Micro-batches chain on the previous one's last backward kernel:
        // the GPU is busy until then, but gradient offloads may still
        // drain while the next micro-batch's parameter prefetch begins
        // (transfer/compute overlap across the seam). One optimizer step
        // per K micro-batches → K× the tokens.
        let (mut s, all_grads, step) = build_fig1_passes(
            cfg,
            plan,
            &Fig1Shape {
                micro_batches: self.micro_batches,
                micro_labels: true,
                ..Fig1Shape::default()
            },
        );
        s.push(full_model_cpu_step(cfg, plan, all_grads, step));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Policy;
    use crate::model::footprint::Workload;
    use crate::model::presets::tiny_2m;
    use crate::offload::executor::execute;
    use crate::offload::schedules::zero_offload::ZeroOffload;
    use crate::topology::presets::dev_tiny;

    #[test]
    fn k_micro_batches_multiply_tokens_and_amortize_the_step() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(1, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();

        let zo = execute(&topo, &ZeroOffload.build(&topo, &cfg, &plan));
        let ga = execute(&topo, &GradAccum::new(3).build(&topo, &cfg, &plan));

        assert_eq!(ga.report.tokens, 3 * zo.report.tokens);
        // 3 fwd+bwd passes but a single step: strictly between 1× and 3×
        // the single-micro iteration, and never slower per token.
        assert!(ga.report.iter_s > zo.report.iter_s * 1.5);
        assert!(ga.report.iter_s < zo.report.iter_s * 3.0);
        assert!(ga.report.tokens_per_sec() >= zo.report.tokens_per_sec() * 0.999);
    }

    #[test]
    fn phases_overlap_across_micro_batch_seams() {
        // The generalized-breakdown satellite: micro-batch m+1's forward
        // begins while m's gradient offloads (phase "bwd") still drain, so
        // the fwd/bwd extents overlap and extent shares exceed 1 in total —
        // exactly what PhaseBreakdown::shares() could never report.
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(1, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let ex = execute(&topo, &GradAccum::new(3).build(&topo, &cfg, &plan));
        let r = &ex.report;
        assert!(r.overlaps("fwd", "bwd"), "accumulation must interleave phases");
        let total: f64 = r.shares().iter().map(|(_, sh)| sh).sum();
        assert!(total > 1.0, "extent shares must expose the overlap: {total}");
        // the boundary-based triple still partitions by construction
        assert!(r.to_breakdown().is_partition());
    }

    #[test]
    fn schedule_validates_and_scales_linearly_in_k() {
        let topo = dev_tiny();
        let cfg = RunConfig::new(tiny_2m(), Workload::new(2, 2, 256), Policy::DramOnly);
        let plan = MemoryPlan::build(&topo, &cfg).unwrap();
        let l = cfg.model.layers;
        for k in [1, 2, 4] {
            let s = GradAccum::new(k).build(&topo, &cfg, &plan);
            s.validate(&topo).unwrap();
            assert_eq!(s.len(), 2 * k * 7 * l + 1);
        }
    }
}
