//! Ablation: GPU-count scalability beyond the paper's 2-GPU testbed.
//!
//! With N GPUs sharing CXL capacity, per-AIC offered load grows with N;
//! striping across more AICs should keep relative throughput flat while
//! the non-striped per-GPU-affinity layout degrades once GPUs outnumber
//! cards. (The paper's §IV-B claims striping "improves scalability" —
//! this bench quantifies that claim on 1–4 GPUs.)

use cxlfine::mem::Policy;
use cxlfine::model::footprint::Workload;
use cxlfine::model::presets::qwen25_7b;
use cxlfine::offload::{simulate_iteration, MemoryPlan, RunConfig};
use cxlfine::topology::presets::{config_b, with_dram_capacity, with_gpus};
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let mut report = BenchReport::new("ablation_gpu_scaling");
    let mut t = Table::new(&["gpus", "baseline tok/s", "affinity %", "striped %"]);
    let (mut xs, mut aff, mut strp) = (vec![], vec![], vec![]);
    for n in [1usize, 2, 3, 4] {
        let base_topo = with_gpus(config_b(), n);
        let cxl_topo = with_gpus(with_dram_capacity(config_b(), 128 * GIB), n);
        // B=1: the transfer-bound regime where contention matters most
        let w = Workload::new(n, 1, 8192);
        let run = |topo: &cxlfine::topology::SystemTopology, policy| {
            let cfg = RunConfig::new(qwen25_7b(), w, policy);
            let plan = MemoryPlan::build(topo, &cfg).unwrap();
            simulate_iteration(topo, &cfg, &plan).tokens_per_sec()
        };
        let base = run(&base_topo, Policy::DramOnly);
        let affinity = run(&cxl_topo, Policy::CxlAware { striping: false }) / base;
        let striped = run(&cxl_topo, Policy::CxlAware { striping: true }) / base;
        t.row(trow![
            n,
            format!("{base:.0}"),
            format!("{:.1}", 100.0 * affinity),
            format!("{:.1}", 100.0 * striped)
        ]);
        xs.push(n as f64);
        aff.push(affinity);
        strp.push(striped);
    }
    // striping must dominate affinity once GPUs > AICs (n = 3, 4)
    for i in 2..4 {
        assert!(
            strp[i] >= aff[i] - 1e-9,
            "striping should win at {} GPUs: {:.3} vs {:.3}",
            i + 1,
            strp[i],
            aff[i]
        );
    }
    // and striped throughput should stay within 70% of baseline at 4 GPUs
    assert!(strp[3] > 0.5, "striped 4-GPU relative {:.3}", strp[3]);
    println!(
        "4-GPU relative throughput: affinity {:.0}% vs striped {:.0}%",
        aff[3] * 100.0,
        strp[3] * 100.0
    );
    report.section(
        "relative_vs_gpus",
        t,
        points_json(&xs, &[("affinity_rel", &aff), ("striped_rel", &strp)]),
    );
    report.finish();
}
