//! Memory management layer: the paper's contribution.
//!
//! * [`region`] — Table I data classes + placements,
//! * [`striping`] — multi-AIC stripe arithmetic (§IV-B),
//! * [`policy`] — the three legacy policies (DramOnly / NaiveInterleave /
//!   CxlAware, §IV-A) as a compact enum,
//! * [`engine`] — the pluggable [`PlacementEngine`] trait + name registry
//!   every layer above consumes (the legacy policies implement it, plans
//!   byte-identical; new strategies plug in without enum edits),
//! * [`allocator`] — NUMA capacity tracking and region lifecycle (the
//!   `libnuma` stand-in).

pub mod allocator;
pub mod engine;
pub mod policy;
pub mod region;
pub mod striping;

pub use allocator::{AllocError, NumaAllocator};
pub use engine::{AdaptiveSpill, EngineRef, PlacementEngine};
pub use policy::Policy;
pub use region::{Placement, Region, RegionId, RegionRequest, TensorClass};
