//! CPU-side memory timing model for the optimizer step (Fig. 5 / Fig. 7
//! STEP phase).
//!
//! The CPU Adam update streams, per element: 16 B read (fp32 param + grad +
//! two moments are 16 B resident, re-read each step) and 12 B written
//! (param + both moments). The phase is bounded below by a vectorized
//! compute floor and above by the sustained read-modify-write bandwidth of
//! whichever memory node(s) hold the data:
//!
//! `t_elem = max(compute_floor, miss_ramp(W) · t_mem(layout))`
//!
//! * `compute_floor` — cache-resident vectorized update cost (topology
//!   calibration; all optimizer threads active).
//! * `miss_ramp(W)` — fraction of traffic actually served by memory, a
//!   log-linear ramp from `W = LLC` (everything stays cached across steps)
//!   to `W = 8·LLC` (pure streaming). This reproduces Fig. 5's knee: CXL
//!   placement is *free* below ~10–20 M elements and ~4× above.
//! * `t_mem(layout)` — per-element memory time of the placement:
//!   - **Interleaved** (naive `numactl --interleave`): page-granular
//!     round-robin means every scan thread alternates fast and slow pages;
//!     per-node times *add*.
//!   - **Partitioned** (multi-AIC striping, Fig. 8c): contiguous shards
//!     with threads pinned per shard; shards drain in parallel so the
//!     *slowest shard* sets the time, and sizing shards ∝ bandwidth
//!     recovers the aggregate of all channels.

use crate::topology::{NodeId, SystemTopology};

/// Bytes read per Adam element (fp32 p, g, m, v).
pub const ADAM_READ_BYTES: f64 = 16.0;
/// Bytes written per Adam element (fp32 p, m, v).
pub const ADAM_WRITE_BYTES: f64 = 12.0;
/// Total bytes moved per element per step.
pub const ADAM_BYTES_PER_ELEM: f64 = ADAM_READ_BYTES + ADAM_WRITE_BYTES;
/// Resident working-set bytes per element.
pub const ADAM_RESIDENT_BYTES: f64 = 16.0;

/// How a multi-node layout is accessed by the optimizer threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessMode {
    /// Page-granular round-robin (the kernel's default interleave policy).
    Interleaved,
    /// Contiguous shards with thread affinity (our striping).
    Partitioned,
}

/// Placement of the optimizer working set: fractions per node, summing to 1.
#[derive(Clone, Debug)]
pub struct OptLayout {
    pub parts: Vec<(NodeId, f64)>,
    pub mode: AccessMode,
}

impl OptLayout {
    pub fn dram_only() -> Self {
        Self {
            parts: vec![(NodeId(0), 1.0)],
            mode: AccessMode::Partitioned,
        }
    }

    pub fn single_node(node: NodeId) -> Self {
        Self {
            parts: vec![(node, 1.0)],
            mode: AccessMode::Partitioned,
        }
    }

    pub fn interleave(nodes: &[NodeId]) -> Self {
        let f = 1.0 / nodes.len() as f64;
        Self {
            parts: nodes.iter().map(|&n| (n, f)).collect(),
            mode: AccessMode::Interleaved,
        }
    }

    /// Bandwidth-proportional partitioning across `nodes` (Fig. 8c).
    pub fn striped_proportional(topo: &SystemTopology, nodes: &[NodeId]) -> Self {
        let total: f64 = nodes.iter().map(|&n| topo.node(n).cpu_stream_bw).sum();
        Self {
            parts: nodes
                .iter()
                .map(|&n| (n, topo.node(n).cpu_stream_bw / total))
                .collect(),
            mode: AccessMode::Partitioned,
        }
    }

    pub fn validate(&self) {
        let total: f64 = self.parts.iter().map(|(_, f)| *f).sum();
        assert!(
            (total - 1.0).abs() < 1e-6,
            "layout fractions sum to {total}, expected 1"
        );
        for (_, f) in &self.parts {
            assert!(*f >= 0.0);
        }
    }
}

/// The calibrated optimizer timing model.
pub struct OptimizerMemModel<'a> {
    topo: &'a SystemTopology,
}

impl<'a> OptimizerMemModel<'a> {
    pub fn new(topo: &'a SystemTopology) -> Self {
        Self { topo }
    }

    /// Fraction of optimizer traffic served from memory (vs caches) for a
    /// resident working set of `w_bytes`. Log-linear ramp LLC → 8·LLC.
    pub fn miss_ramp(&self, w_bytes: f64) -> f64 {
        let llc = self.topo.cpu.llc_bytes as f64;
        if w_bytes <= llc {
            return 0.0;
        }
        let x = (w_bytes / llc).log2() / 3.0; // 8×LLC → log2(8)/3 = 1
        x.clamp(0.0, 1.0)
    }

    /// Per-element memory service time (seconds) of a layout at full miss.
    fn mem_time_per_elem(&self, layout: &OptLayout) -> f64 {
        layout.validate();
        match layout.mode {
            AccessMode::Interleaved => layout
                .parts
                .iter()
                .map(|(n, f)| f * ADAM_BYTES_PER_ELEM / self.topo.node(*n).cpu_stream_bw)
                .sum(),
            AccessMode::Partitioned => layout
                .parts
                .iter()
                .map(|(n, f)| f * ADAM_BYTES_PER_ELEM / self.topo.node(*n).cpu_stream_bw)
                .fold(0.0, f64::max),
        }
    }

    /// Wall-clock seconds for one optimizer step over `elements` Adam
    /// elements placed as `layout`.
    pub fn step_time(&self, elements: u64, layout: &OptLayout) -> f64 {
        let n = elements as f64;
        let w = n * ADAM_RESIDENT_BYTES;
        let compute = self.topo.cpu.adam_compute_ns_per_elem * 1e-9;
        let mem = self.miss_ramp(w) * self.mem_time_per_elem(layout);
        n * compute.max(mem)
    }

    /// Effective elements/second for reporting.
    pub fn throughput(&self, elements: u64, layout: &OptLayout) -> f64 {
        elements as f64 / self.step_time(elements, layout)
    }

    /// Pure streaming time (no reuse, always memory-bound) for `bytes`
    /// spread as `layout` — used for the post-step fp32→bf16 parameter
    /// cast and CPU-side gradient upcast.
    pub fn stream_time(&self, bytes: f64, layout: &OptLayout) -> f64 {
        layout.validate();
        match layout.mode {
            AccessMode::Interleaved => layout
                .parts
                .iter()
                .map(|(n, f)| f * bytes / self.topo.node(*n).cpu_stream_bw)
                .sum(),
            AccessMode::Partitioned => layout
                .parts
                .iter()
                .map(|(n, f)| f * bytes / self.topo.node(*n).cpu_stream_bw)
                .fold(0.0, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::config_a;

    fn cxl0() -> NodeId {
        NodeId(1)
    }

    #[test]
    fn small_n_parity_between_dram_and_cxl() {
        // Fig. 5 left region: below the cache knee, placement is irrelevant.
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let n = 2_000_000; // 32 MB resident < 108 MB LLC
        let t_dram = m.step_time(n, &OptLayout::dram_only());
        let t_cxl = m.step_time(n, &OptLayout::single_node(cxl0()));
        assert!((t_cxl / t_dram - 1.0).abs() < 1e-9, "small-N parity broken");
    }

    #[test]
    fn large_n_cxl_roughly_4x() {
        // Fig. 5 right region: ≥ ~4× inflation for CXL-resident data.
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let n = 200_000_000; // 3.2 GB resident ≫ 8×LLC
        let ratio = m.step_time(n, &OptLayout::single_node(cxl0()))
            / m.step_time(n, &OptLayout::dram_only());
        assert!((3.2..4.8).contains(&ratio), "large-N CXL ratio {ratio}");
    }

    #[test]
    fn knee_lands_in_tens_of_millions() {
        // The divergence point (CXL ≥ 1.5× DRAM) should fall in the
        // 5–40 M element band ("roughly 20 million" in §III-A).
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let mut knee = None;
        for exp in 0..400 {
            let n = (1e6 * 1.04f64.powi(exp)) as u64;
            let r = m.step_time(n, &OptLayout::single_node(cxl0()))
                / m.step_time(n, &OptLayout::dram_only());
            if r >= 1.5 {
                knee = Some(n);
                break;
            }
        }
        let knee = knee.expect("CXL never diverged");
        assert!(
            (5_000_000..40_000_000).contains(&knee),
            "knee at {knee} elements"
        );
    }

    #[test]
    fn dram_stays_near_compute_floor() {
        // Fig. 5 DRAM line is nearly flat in time-per-element.
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let t_small = m.step_time(1_000_000, &OptLayout::dram_only()) / 1e6;
        let t_large = m.step_time(500_000_000, &OptLayout::dram_only()) / 5e8;
        assert!(t_large / t_small < 1.2, "DRAM per-element time rose {}x", t_large / t_small);
    }

    #[test]
    fn interleave_worse_than_stripe_at_scale() {
        // Fig. 8c: bandwidth-proportional striping beats naive interleave.
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let nodes = [NodeId(0), cxl0()];
        let n = 400_000_000;
        let t_inter = m.step_time(n, &OptLayout::interleave(&nodes));
        let t_stripe = m.step_time(n, &OptLayout::striped_proportional(&topo, &nodes));
        assert!(
            t_stripe < t_inter,
            "stripe {t_stripe} should beat interleave {t_inter}"
        );
    }

    #[test]
    fn proportional_stripe_matches_dram_at_scale() {
        // Fig. 10a: with shards ∝ bandwidth the slow node never dominates;
        // the step stays at (or below) the DRAM-only time.
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let nodes = [NodeId(0), cxl0()];
        let n = 400_000_000;
        let t_stripe = m.step_time(n, &OptLayout::striped_proportional(&topo, &nodes));
        let t_dram = m.step_time(n, &OptLayout::dram_only());
        assert!(t_stripe <= t_dram * 1.01, "stripe {t_stripe} vs dram {t_dram}");
    }

    #[test]
    fn miss_ramp_monotone_and_bounded() {
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let llc = topo.cpu.llc_bytes as f64;
        assert_eq!(m.miss_ramp(llc * 0.5), 0.0);
        assert_eq!(m.miss_ramp(llc), 0.0);
        let mut last = 0.0;
        for mult in [1.1, 2.0, 4.0, 8.0, 16.0] {
            let r = m.miss_ramp(llc * mult);
            assert!(r >= last && (0.0..=1.0).contains(&r));
            last = r;
        }
        assert_eq!(m.miss_ramp(llc * 8.0), 1.0);
    }

    #[test]
    #[should_panic(expected = "fractions sum")]
    fn layout_fractions_validated() {
        let topo = config_a();
        let m = OptimizerMemModel::new(&topo);
        let bad = OptLayout {
            parts: vec![(NodeId(0), 0.3)],
            mode: AccessMode::Partitioned,
        };
        m.step_time(1000, &bad);
    }
}
