//! The tensor-access IR: per-region traffic/liveness profiles measured
//! from a schedule DAG.
//!
//! The paper's §IV allocator reasons about *what the workload does to each
//! tensor* — how many bytes ride DMA engines per iteration, how much CPU
//! read-modify-write traffic the optimizer issues, and when in the
//! iteration the tensor is live at all. Before this pass, those facts were
//! approximated by a hard-coded boolean on six
//! [`crate::mem::TensorClass`] variants;
//! now they are *derived*: [`profile_schedule`] walks any
//! [`crate::offload::Schedule`] and folds every [`RegionTouch`] annotation
//! into one [`AccessProfile`] per region. Placement engines consume the
//! profiles through [`crate::mem::PlacementEngine::place_profiled`], and
//! the allocator's timeline accounting consumes the liveness windows.
//!
//! Profiles are **placement-independent**: every quantity comes from op
//! payloads (byte counts, element counts, phase indices), never from
//! stripe fractions or layouts — so a schedule built against a throwaway
//! all-DRAM probe plan yields the same profiles as the final schedule
//! (pinned by tests in `offload/plan.rs`). That is what breaks the
//! profile→placement→schedule cycle: profile first against the probe,
//! place with the profiles, then build the real schedule.

use std::collections::BTreeMap;

use super::region::{Lifetime, RegionId};
use crate::offload::schedule::{Op, RegionTouch, Schedule};
use crate::sim::fabric::Dir;

/// Measured per-iteration access behaviour of one memory region.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccessProfile {
    /// Bytes DMA'd host→GPU per iteration (parameter streams, reloads).
    pub h2d_bytes: f64,
    /// Bytes DMA'd GPU→host per iteration (checkpoint/gradient offloads).
    pub d2h_bytes: f64,
    /// Elements read-modify-written by the CPU optimizer per iteration.
    pub cpu_rmw_elements: u64,
    /// Bytes moved by pure CPU streaming passes (fp32→bf16 casts).
    pub cpu_stream_bytes: f64,
    /// Number of schedule ops that move traffic for this region
    /// (keepalive touches extend the lifetime but do not count).
    pub touches: u32,
    /// Phases of the schedule during which the region is live.
    pub lifetime: Lifetime,
}

impl AccessProfile {
    fn at_phase(phase: u32) -> Self {
        Self {
            h2d_bytes: 0.0,
            d2h_bytes: 0.0,
            cpu_rmw_elements: 0,
            cpu_stream_bytes: 0.0,
            touches: 0,
            lifetime: Lifetime::spanning(phase, phase),
        }
    }

    /// Total DMA traffic per iteration, both directions.
    pub fn dma_bytes(&self) -> f64 {
        self.h2d_bytes + self.d2h_bytes
    }

    /// Is the region on the CPU optimizer's critical path? This is the
    /// *measured* replacement for
    /// [`crate::mem::TensorClass::latency_critical`]: any RMW element
    /// traffic means the region eats the CXL latency penalty (§III-A),
    /// regardless of what class the request claimed.
    pub fn latency_critical(&self) -> bool {
        self.cpu_rmw_elements > 0
    }

    /// Hotness rank used for spill ordering: RMW bytes dominate (they are
    /// latency-bound), then CPU stream bytes, then DMA bytes (bandwidth-
    /// bound, most tolerant of CXL placement).
    pub fn heat(&self) -> f64 {
        use crate::sim::memmodel::ADAM_BYTES_PER_ELEM;
        self.cpu_rmw_elements as f64 * ADAM_BYTES_PER_ELEM * 4.0
            + self.cpu_stream_bytes * 2.0
            + self.dma_bytes()
    }
}

/// Everything [`profile_schedule`] learns about one schedule.
#[derive(Clone, Debug, Default)]
pub struct ScheduleProfiles {
    /// Phase names of the profiled schedule, in declaration order (the
    /// index space every [`Lifetime`] lives in).
    pub phases: Vec<String>,
    /// One profile per region the schedule touches, keyed by the region
    /// ids the builder annotated.
    pub by_region: BTreeMap<RegionId, AccessProfile>,
}

impl ScheduleProfiles {
    pub fn n_phases(&self) -> usize {
        self.phases.len()
    }

    pub fn get(&self, region: RegionId) -> Option<&AccessProfile> {
        self.by_region.get(&region)
    }
}

/// Walk a schedule DAG and compute one [`AccessProfile`] per region its
/// nodes touch. Nodes are visited in index order, so byte totals are
/// bit-deterministic. Ops without touch annotations contribute nothing.
pub fn profile_schedule(sched: &Schedule) -> ScheduleProfiles {
    let mut by_region: BTreeMap<RegionId, AccessProfile> = BTreeMap::new();
    for node in &sched.nodes {
        let phase = node.phase as u32;
        for touch in &node.touches {
            let p = by_region
                .entry(touch.region())
                .or_insert_with(|| AccessProfile::at_phase(phase));
            p.lifetime.cover(phase);
            match touch {
                RegionTouch::Dma(_) => {
                    if let Op::Transfer { dir, bytes, .. } = &node.op {
                        match dir {
                            Dir::HostToGpu => p.h2d_bytes += bytes,
                            Dir::GpuToHost => p.d2h_bytes += bytes,
                        }
                        p.touches += 1;
                    }
                }
                RegionTouch::CpuRmw(_) => {
                    if let Op::CpuStep { adam_elements, .. } = &node.op {
                        p.cpu_rmw_elements += adam_elements;
                        p.touches += 1;
                    }
                }
                RegionTouch::CpuStream { stream, .. } => {
                    if let Op::CpuStep { streams, .. } = &node.op {
                        p.cpu_stream_bytes += streams[*stream].0;
                        p.touches += 1;
                    }
                }
                RegionTouch::Keepalive(_) => {}
            }
        }
    }
    ScheduleProfiles {
        phases: sched.phases.clone(),
        by_region,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::schedule::OpNode;
    use crate::sim::memmodel::OptLayout;
    use crate::topology::presets::dev_tiny;
    use crate::topology::{GpuId, NodeId};

    fn xfer(dir: Dir, bytes: f64, phase: usize, touches: Vec<RegionTouch>) -> OpNode {
        OpNode {
            op: Op::Transfer {
                gpu: GpuId(0),
                stripes: vec![(NodeId(0), 1.0)],
                dir,
                bytes,
            },
            deps: vec![],
            name: "t".into(),
            lane: "gpu0/h2d".into(),
            phase,
            ends_phase: false,
            touches,
        }
    }

    #[test]
    fn profiles_fold_traffic_per_region_and_direction() {
        let r0 = RegionId(0);
        let r1 = RegionId(1);
        let mut s = Schedule::new(0);
        let fwd = s.phase("fwd");
        let bwd = s.phase("bwd");
        let step = s.phase("step");
        s.push(xfer(Dir::HostToGpu, 100.0, fwd, vec![RegionTouch::Dma(r0)]));
        s.push(xfer(Dir::HostToGpu, 50.0, bwd, vec![RegionTouch::Dma(r0)]));
        s.push(xfer(Dir::GpuToHost, 30.0, bwd, vec![RegionTouch::Dma(r1)]));
        s.push(OpNode {
            op: Op::CpuStep {
                adam_elements: 1000,
                adam_layout: OptLayout::dram_only(),
                streams: vec![(400.0, OptLayout::dram_only()), (200.0, OptLayout::dram_only())],
            },
            deps: vec![],
            name: "step".into(),
            lane: "cpu/step".into(),
            phase: step,
            ends_phase: true,
            touches: vec![
                RegionTouch::CpuRmw(RegionId(2)),
                RegionTouch::CpuStream {
                    region: r0,
                    stream: 1,
                },
                RegionTouch::Keepalive(r1),
            ],
        });
        s.validate(&dev_tiny()).unwrap();
        let prof = profile_schedule(&s);
        assert_eq!(prof.n_phases(), 3);
        assert_eq!(prof.by_region.len(), 3);

        let p0 = prof.get(r0).unwrap();
        assert_eq!(p0.h2d_bytes, 150.0);
        assert_eq!(p0.d2h_bytes, 0.0);
        assert_eq!(p0.cpu_stream_bytes, 200.0);
        assert_eq!(p0.touches, 3);
        assert_eq!(p0.lifetime, Lifetime::spanning(0, 2));
        assert!(!p0.latency_critical());

        // keepalive extends r1's lifetime into step without traffic
        let p1 = prof.get(r1).unwrap();
        assert_eq!(p1.d2h_bytes, 30.0);
        assert_eq!(p1.touches, 1, "keepalive must not count as a touch");
        assert_eq!(p1.lifetime, Lifetime::spanning(1, 2));

        let p2 = prof.get(RegionId(2)).unwrap();
        assert_eq!(p2.cpu_rmw_elements, 1000);
        assert!(p2.latency_critical());
        assert_eq!(p2.lifetime, Lifetime::spanning(2, 2));
        assert!(p2.heat() > p1.heat(), "RMW traffic must outrank DMA");
    }

    #[test]
    fn unannotated_schedule_profiles_empty() {
        let mut s = Schedule::new(0);
        s.phase("fwd");
        s.push(xfer(Dir::HostToGpu, 100.0, 0, vec![]));
        let prof = profile_schedule(&s);
        assert!(prof.by_region.is_empty());
        assert_eq!(prof.n_phases(), 1);
    }
}
