"""Pallas fused linear-cross-entropy (the Liger-kernel insight, TPU-style).

The naive path materializes the ``[tokens, vocab]`` logit matrix — at long
context this intermediate alone rivals the model's weights (§II-A). The
fused kernel never does: for each row tile it streams the tied-head weight
matrix vocab-tile by vocab-tile through VMEM, maintaining three running
statistics per row — max logit ``m``, scaled exp-sum ``l``, and the label's
logit — and emits ``lse = log l + m`` and ``label_logit``. Peak live memory
is ``O(block_rows · block_vocab)`` instead of ``O(tokens · vocab)``.

Backward recomputes through the jnp oracle (custom_vjp), which *does*
materialize logits — acceptable at the artifact model sizes; a production
TPU deployment would chunk the backward the same way.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _ce_kernel(x_ref, emb_ref, labels_ref, lse_ref, ll_ref, *, block_v):
    """One row-tile grid step: stream vocab tiles, keep running stats."""
    rows = x_ref.shape[0]
    vocab = emb_ref.shape[0]
    x = x_ref[:, :].astype(jnp.float32)          # [rows, hidden] in VMEM
    labels = labels_ref[:]                        # [rows] int32

    n_v = vocab // block_v

    def body(vi, carry):
        m_prev, l_prev, ll_prev = carry
        w = emb_ref[pl.ds(vi * block_v, block_v), :].astype(jnp.float32)
        logits = x @ w.T                          # [rows, block_v] — MXU
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.exp(
            logits - m_new[:, None]
        ).sum(axis=-1)
        # pick out the label logit if it falls inside this vocab tile
        cols = vi * block_v + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_v), 1
        )
        hit = cols == labels[:, None]
        ll_new = jnp.where(hit.any(axis=-1), (logits * hit).sum(axis=-1), ll_prev)
        return m_new, l_new, ll_new

    m0 = jnp.full((rows,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rows,), jnp.float32)
    ll0 = jnp.zeros((rows,), jnp.float32)
    m, l, ll = jax.lax.fori_loop(0, n_v, body, (m0, l0, ll0))
    lse_ref[:] = jnp.log(l) + m
    ll_ref[:] = ll


def _pick_block(n, want):
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def fused_ce_stats(x, emb, labels, block_rows=128, block_vocab=512):
    """Streaming (lse, label_logit) per row; never materializes logits."""
    tokens, hidden = x.shape
    vocab = emb.shape[0]
    assert emb.shape[1] == hidden and labels.shape == (tokens,)
    br = _pick_block(tokens, block_rows)
    bv = _pick_block(vocab, block_vocab)
    kernel = functools.partial(_ce_kernel, block_v=bv)
    lse, ll = pl.pallas_call(
        kernel,
        grid=(tokens // br,),
        in_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),      # row tile
            pl.BlockSpec((vocab, hidden), lambda i: (0, 0)),   # W (streamed)
            pl.BlockSpec((br,), lambda i: (i,)),               # labels tile
        ],
        out_specs=[
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tokens,), jnp.float32),
            jax.ShapeDtypeStruct((tokens,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, emb, labels)
    return lse, ll


@jax.custom_vjp
def fused_linear_cross_entropy(x, emb, labels):
    """Mean cross-entropy of ``x @ embᵀ`` against ``labels`` — fused."""
    lse, ll = fused_ce_stats(x, emb, labels)
    return jnp.mean(lse - ll)


def _fce_fwd(x, emb, labels):
    return fused_linear_cross_entropy(x, emb, labels), (x, emb, labels)


def _fce_bwd(res, g):
    x, emb, labels = res
    _, vjp = jax.vjp(lambda x, emb: ref.linear_cross_entropy(x, emb, labels), x, emb)
    dx, demb = vjp(g)
    return dx, demb, None


fused_linear_cross_entropy.defvjp(_fce_fwd, _fce_bwd)


def peak_live_floats(tokens, hidden, vocab, block_rows=128, block_vocab=512):
    """Structural perf metric (§8): fused peak vs naive ``tokens·vocab``."""
    br = _pick_block(tokens, block_rows)
    bv = _pick_block(vocab, block_vocab)
    return br * hidden + bv * hidden + br * bv + 3 * br
