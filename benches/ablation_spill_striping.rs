//! Ablation: Fig. 8c — striping *spilled optimizer state* across
//! DRAM + multiple AICs vs naive alternatives.
//!
//! When fp32 P/G/O exceed local DRAM, the spill's placement decides STEP
//! time: sequential fill (everything-extra on one AIC), naive interleave,
//! or bandwidth-proportional partitioning (ours). The proportional split
//! should track max(shard_time) ≈ the DRAM-only time.

use cxlfine::mem::{AdaptiveSpill, PlacementEngine, RegionRequest, TensorClass};
use cxlfine::sim::memmodel::{AccessMode, OptLayout, OptimizerMemModel};
use cxlfine::topology::presets::config_b;
use cxlfine::topology::NodeId;
use cxlfine::trow;
use cxlfine::util::bench::{points_json, BenchReport};
use cxlfine::util::table::Table;
use cxlfine::util::units::GIB;

fn main() {
    let mut report = BenchReport::new("ablation_spill_striping");
    let topo = config_b();
    let mm = OptimizerMemModel::new(&topo);
    let nodes = [NodeId(0), NodeId(1), NodeId(2)];
    let elements: u64 = 12_000_000_000 / 16; // a 12B model's PGO working set

    // spill fraction sweep: how much of PGO falls off DRAM
    let mut t = Table::new(&[
        "dram_fraction",
        "seq-fill (s)",
        "interleave (s)",
        "proportional (s)",
        "prop vs dram-only",
    ]);
    let dram_only = mm.step_time(elements, &OptLayout::dram_only());
    let (mut xs, mut seqv, mut intv, mut propv) = (vec![], vec![], vec![], vec![]);
    for dram_frac in [0.9f64, 0.8, 0.7, 0.6, 0.5] {
        let spill = 1.0 - dram_frac;
        // sequential: all spill on AIC 0
        let seq = OptLayout {
            parts: vec![
                (nodes[0], dram_frac),
                (nodes[1], spill),
            ],
            mode: AccessMode::Partitioned,
        };
        // interleave across all three (page round-robin over the spill +
        // dram mix — the numactl default behaviour)
        let inter = OptLayout::interleave(&nodes);
        // bandwidth-proportional split of the WHOLE set (ours, Fig. 8c)
        let prop = OptLayout::striped_proportional(&topo, &nodes);
        let ts = mm.step_time(elements, &seq);
        let ti = mm.step_time(elements, &inter);
        let tp = mm.step_time(elements, &prop);
        t.row(trow![
            format!("{dram_frac:.1}"),
            format!("{ts:.3}"),
            format!("{ti:.3}"),
            format!("{tp:.3}"),
            format!("{:.2}x", tp / dram_only)
        ]);
        xs.push(dram_frac);
        seqv.push(ts);
        intv.push(ti);
        propv.push(tp);
    }
    // ours never loses to either alternative and stays at the DRAM roofline
    for i in 0..xs.len() {
        assert!(propv[i] <= seqv[i] + 1e-9, "prop must beat seq-fill");
        assert!(propv[i] <= intv[i] + 1e-9, "prop must beat interleave");
    }
    let worst = propv.iter().cloned().fold(0.0, f64::max);
    assert!(
        worst <= dram_only * 1.01,
        "proportional striping should hold the DRAM-only time: {worst} vs {dram_only}"
    );
    println!(
        "proportional spill striping holds STEP at {:.3}s (dram-only {:.3}s)",
        worst, dram_only
    );
    report.section(
        "step_time_vs_spill",
        t,
        points_json(
            &xs,
            &[("seq_fill_s", &seqv), ("interleave_s", &intv), ("proportional_s", &propv)],
        ),
    );

    // ---- adaptive engine: spill placement under asymmetric AIC fill ----
    // Drive the actual `adaptive-spill` PlacementEngine (not just the
    // timing model): as AIC0 fills up, the spill share it receives must
    // shrink monotonically while the step time of the resulting layout
    // stays within a whisker of the static bandwidth-proportional split.
    let engine = AdaptiveSpill;
    let spill = 64 * GIB;
    let mut t2 = Table::new(&["aic0_free_frac", "aic0 share", "aic1 share", "step vs static"]);
    let (mut fx, mut share0) = (vec![], vec![]);
    let static_prop = OptLayout::striped_proportional(&topo, &[NodeId(1), NodeId(2)]);
    let t_static = mm.step_time(spill / 16, &static_prop);
    let mut last_share = f64::INFINITY;
    for free_frac in [1.0f64, 0.75, 0.5, 0.25] {
        let free = vec![
            0u64, // DRAM exhausted → the whole region is spill
            (topo.node(NodeId(1)).capacity as f64 * free_frac) as u64,
            topo.node(NodeId(2)).capacity,
        ];
        let req = RegionRequest::new("pgo-spill", TensorClass::OptimizerStates, spill);
        let p = engine.place(&topo, &req, &free).expect("spill fits");
        assert_eq!(p.mode, AccessMode::Partitioned);
        let s0 = p.bytes_on(NodeId(1)) as f64 / spill as f64;
        let s1 = p.bytes_on(NodeId(2)) as f64 / spill as f64;
        assert!(s0 <= last_share + 1e-9, "aic0 share must shrink as it fills");
        last_share = s0;
        let layout = OptLayout {
            parts: p
                .parts
                .iter()
                .map(|(n, b)| (*n, *b as f64 / spill as f64))
                .collect(),
            mode: AccessMode::Partitioned,
        };
        let t_adaptive = mm.step_time(spill / 16, &layout);
        t2.row(trow![
            format!("{free_frac:.2}"),
            format!("{:.1}%", 100.0 * s0),
            format!("{:.1}%", 100.0 * s1),
            format!("{:.2}x", t_adaptive / t_static)
        ]);
        fx.push(free_frac);
        share0.push(s0);
        // both AICs have equal bandwidth here, so any split between them
        // costs the same per-byte; adaptive must stay within 2.5x of the
        // static split even in the most lopsided case (and buys headroom
        // for the NEXT allocation, which the static split destroys).
        assert!(t_adaptive <= t_static * 2.5, "adaptive step time exploded");
    }
    assert!(
        *share0.last().unwrap() <= 0.21,
        "a 75%-full AIC must receive a small spill share: {share0:?}"
    );
    report.section("adaptive_spill_shares", t2, points_json(&fx, &[("aic0_share", &share0)]));
    println!(
        "adaptive-spill shifts spill off filling AICs (share {:.2} → {:.2})",
        share0[0],
        share0.last().unwrap()
    );
    report.finish();
}
