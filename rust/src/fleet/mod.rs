//! The fleet layer: a discrete-event, multi-tenant simulator sitting
//! *above* the per-job machinery — many fine-tuning jobs arriving over
//! time on one shared DRAM + CXL host (the production regime the ROADMAP
//! targets, which neither the paper nor a single-iteration pipeline
//! models).
//!
//! * [`job`] — job specs, replayable JSON traces, and the seeded
//!   synthetic workload generator (Poisson-ish arrivals over a job mix),
//! * [`host`] — the long-lived multi-job host: one shared
//!   [`crate::mem::NumaAllocator`] plus GPU-slot accounting; admission
//!   plans are built against its capacity "free view",
//! * [`scheduler`] — the pluggable admission-policy registry (`fifo`,
//!   `backfill`, `placement-aware`),
//! * [`faults`] — replayable hardware-fault traces (link degrades, CXL
//!   AIC hot-remove/hot-add, capacity squeezes), the accumulated
//!   [`faults::Degradation`] view, and the recovery-policy registry
//!   (`fail-stop`, `checkpoint-restart`, `evacuate`),
//! * [`sim`] — the event loop (a thin adapter over
//!   [`crate::simcore`]'s `EventQueue`/`EventKey` since DESIGN.md §14)
//!   and the memoized per-(config, engine, degradation) cost calibrator
//!   (one real `offload::executor` run per cell),
//! * [`reference`] — the frozen pre-`simcore` event loop, kept as the
//!   differential oracle the parity suite and the fleet bench diff
//!   against,
//! * [`metrics`] — per-job records, occupancy curves, makespan / JCT /
//!   goodput / lost-work statistics, digests and JSON.
//!
//! The cluster-DES shape follows the dslab family of simulators: an event
//! heap owns the clock, resources are capacity counters, and policies are
//! pure decision plugins consulted at every arrival and completion.
//! Determinism is a contract here exactly as in `sim::flow`: identical
//! traces produce bit-identical [`FleetResult::digest`]s across reruns
//! and thread counts.

pub mod faults;
pub mod host;
pub mod job;
pub mod metrics;
pub mod reference;
pub mod scheduler;
pub mod sim;

pub use faults::{
    pinned_faults_from_baseline, Degradation, FaultEvent, FaultGen, FaultKind, FaultTrace,
    RecoveryAction, RecoveryPolicy, RecoveryRef,
};
pub use host::FleetHost;
pub use job::{FleetTrace, JobSpec, TraceGen};
pub use metrics::{FleetResult, JobRecord, JobStatus, OccupancySample};
pub use scheduler::{AdmissionProbe, PolicyRef, SchedPolicy};
pub use sim::{
    mixed_trace_with_xl, simulate_fleet, simulate_fleet_faulted, CalCost, Calibrator,
};
