//! Memory management layer: the paper's contribution.
//!
//! * [`region`] — Table I data classes + placements,
//! * [`striping`] — multi-AIC stripe arithmetic (§IV-B),
//! * [`policy`] — the three legacy policies (DramOnly / NaiveInterleave /
//!   CxlAware, §IV-A) as a compact enum,
//! * [`engine`] — the pluggable [`PlacementEngine`] trait + name registry
//!   every layer above consumes (the legacy policies implement it, plans
//!   byte-identical; new strategies plug in without enum edits),
//! * [`profile`] — the tensor-access IR: per-region [`AccessProfile`]s
//!   measured from a schedule DAG, consumed by profile-driven engines and
//!   the allocator's timeline accounting,
//! * [`allocator`] — NUMA capacity tracking and region lifecycle (the
//!   `libnuma` stand-in), with per-phase timeline accounting.

pub mod allocator;
pub mod engine;
pub mod policy;
pub mod profile;
pub mod region;
pub mod striping;

pub use allocator::{AllocError, NodeShortfall, NumaAllocator};
pub use engine::{AdaptiveSpill, EngineRef, PlacementEngine, ProfileAware};
pub use policy::Policy;
pub use profile::{profile_schedule, AccessProfile, ScheduleProfiles};
pub use region::{Lifetime, Placement, Region, RegionId, RegionRequest, TensorClass};
